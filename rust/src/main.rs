//! `wildcat` — the coordinator CLI (leader entrypoint).
//!
//! Subcommands:
//! * `info`    — print the artifact manifest + platform
//! * `serve`   — run the serving coordinator on a synthetic Poisson trace
//!               (native or PJRT backend) and report serving metrics
//! * `cluster` — run the multi-replica serving tier: a replica pool
//!               behind a routing policy, driven by a trace replay
//! * `attn`    — one-shot WildCat-vs-exact attention comparison
//! * `tasks`   — evaluate a KV compression policy on the 13-task suite
//! * `bench`   — run the paper benches; `--smoke` runs the whole suite in
//!               seconds and writes machine-readable `BENCH_*.json`
//! * `obs`     — validate observability artifacts written by the serving
//!               commands (Chrome traces, metrics-series JSONL, metrics
//!               snapshots with quality blocks); see docs/OBSERVABILITY.md

use std::sync::Arc;
use std::time::{Duration, Instant};
use wildcat::attention::{exact_attention, wildcat_attention, WildcatParams};
use wildcat::cluster::{
    replay, Clock, FaultConfig, FaultPlan, Pacing, ReplayConfig, ReplicaPool, Router,
    RouterConfig, RoutingPolicy, Supervisor,
};
use wildcat::coordinator::{Server, ServerConfig};
use wildcat::kvcache::compressor_by_name;
use wildcat::kvpool::{
    budget_floats_from_mb, spill_budget_bytes_from_mb, KvPoolConfig, PoolSnapshot, SpillParams,
};
use wildcat::linalg::norms::max_abs_diff;
use wildcat::model::{ModelConfig, Transformer};
use wildcat::obs::{self, MetricsSampler, QualityConfig};
use wildcat::rng::Rng;
use wildcat::util::cli::Args;
use wildcat::util::json::Json;
use wildcat::workload::{gaussian_qkv, poisson_trace, shaped_trace, task_suite, TraceShape};

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let cmd = args.positional().first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "info" => cmd_info(&args),
        "serve" => cmd_serve(&args),
        "cluster" => cmd_cluster(&args),
        "attn" => cmd_attn(&args),
        "tasks" => cmd_tasks(&args),
        "bench" => cmd_bench(&args),
        "obs" => cmd_obs(&args),
        _ => {
            println!(
                "wildcat — near-linear attention serving coordinator\n\
                 usage: wildcat <info|serve|cluster|attn|tasks|bench|obs> [--options]\n\
                 see README.md for per-command options"
            );
            Ok(())
        }
    }
}

/// Shared `--kv-budget-mb` / `--prefix-sharing` parsing for the serving
/// commands: the per-replica KV pool budget (0 / absent = unbounded) and
/// whether prompts are deduplicated through the pool's radix prefix index.
///
/// `--spill-budget-mb MB` (with optional `--spill-dir PATH`, default
/// `wildcat-spill/`) arms the spill-to-disk tier: evicted prefix blocks
/// are written to a byte-budgeted cold store instead of being destroyed,
/// and paged back on later prefix hits. 0 / absent = off, and an off run
/// is bit-identical to a build without the tier.
fn pool_config_from_args(args: &Args) -> anyhow::Result<KvPoolConfig> {
    let mut pool = KvPoolConfig::default();
    pool.budget_floats = budget_floats_from_mb(args.get_parse::<f64>("kv-budget-mb", 0.0));
    pool.prefix_sharing = match args.get_or("prefix-sharing", "on").as_str() {
        "on" | "true" | "1" => true,
        "off" | "false" | "0" => false,
        other => anyhow::bail!("--prefix-sharing: expected on/off, got {other:?}"),
    };
    pool.compress_budget = args.get_parse::<usize>("kv-compress-budget", pool.compress_budget);
    let spill_mb = args.get_parse::<f64>("spill-budget-mb", 0.0);
    if spill_mb > 0.0 {
        anyhow::ensure!(
            pool.prefix_sharing,
            "--spill-budget-mb requires --prefix-sharing on (spill caches radix prefix blocks)"
        );
        pool.spill = Some(SpillParams {
            dir: std::path::PathBuf::from(args.get_or("spill-dir", "wildcat-spill")),
            budget_bytes: spill_budget_bytes_from_mb(spill_mb),
            replica: 0,
        });
    }
    Ok(pool)
}

/// Shared `--prefill-skip on|off` parsing for the serving commands:
/// whether admission resumes prefill from KV-pool prefix hits (computing
/// only the unmatched tail) instead of recomputing the whole prompt.
/// Defaults to on; only takes effect when the backend supports resumed
/// prefill and `--prefix-sharing` is on.
fn prefill_skip_from_args(args: &Args) -> anyhow::Result<bool> {
    Ok(match args.get_or("prefill-skip", "on").as_str() {
        "on" | "true" | "1" => true,
        "off" | "false" | "0" => false,
        other => anyhow::bail!("--prefill-skip: expected on/off, got {other:?}"),
    })
}

/// Shared `--audit-rate N` / `--audit-slo-abs-err E` parsing for the
/// serving commands: the approximation-quality auditor samples 1-in-N
/// decode steps / compression folds (0 = off, the default) and, when an
/// SLO threshold is given, degrades gracefully (coreset budget raised,
/// compression rung paused) while the windowed p99 audited error is in
/// breach. Sites are sampled from the run seed, so a rerun audits the
/// same work.
fn quality_config_from_args(args: &Args, seed: u64) -> QualityConfig {
    QualityConfig {
        rate: args.get_parse::<u32>("audit-rate", 0),
        slo_abs_err: args.get_parse::<f64>("audit-slo-abs-err", 0.0),
        seed,
    }
}

/// Shared `--trace-json PATH [--trace-capacity N]` setup for the serving
/// commands: enables the process-wide tracer (clearing any stale ring)
/// before the run starts. Returns the output path when tracing is on.
fn trace_setup(args: &Args) -> Option<String> {
    let path = args.get("trace-json")?.to_string();
    let cap = args.get_parse::<usize>("trace-capacity", wildcat::obs::trace::DEFAULT_CAPACITY);
    wildcat::obs::trace::global().enable_with_capacity(cap);
    Some(path)
}

/// Drain the global tracer and write a Chrome trace-event JSON document
/// (load it in Perfetto or chrome://tracing).
fn trace_finish(path: &str) -> anyhow::Result<()> {
    let tracer = wildcat::obs::trace::global();
    tracer.set_enabled(false);
    let buf = tracer.drain();
    let doc = wildcat::obs::chrome_trace(&buf);
    std::fs::write(path, doc.to_string_compact())?;
    println!(
        "trace written to {path}: {} event(s) retained, {} dropped \
         (load in Perfetto / chrome://tracing)",
        buf.events.len(),
        buf.dropped
    );
    Ok(())
}

/// Shared `--metrics-series PATH [--metrics-interval-ms N]` setup: start
/// the JSONL sampler over `snap`, or return `None` when not requested.
fn sampler_setup<F>(args: &Args, run: &Json, snap: F) -> anyhow::Result<Option<MetricsSampler>>
where
    F: Fn() -> Json + Send + 'static,
{
    match args.get("metrics-series") {
        Some(path) => {
            let ms = args.get_parse::<u64>("metrics-interval-ms", 100);
            let interval = Duration::from_millis(ms);
            Ok(Some(MetricsSampler::start(path, run.clone(), interval, snap)?))
        }
        None => Ok(None),
    }
}

/// Stop a running sampler (if any) and report where the series landed.
fn sampler_finish(args: &Args, sampler: Option<MetricsSampler>) -> anyhow::Result<()> {
    if let Some(s) = sampler {
        let n = s.stop()?;
        if let Some(path) = args.get("metrics-series") {
            println!("metrics series written to {path} ({n} samples)");
        }
    }
    Ok(())
}

/// `wildcat obs [--trace PATH] [--series PATH] [--metrics PATH]`
///
/// Validate observability artifacts produced by `serve`/`cluster`:
/// `--trace` checks a Chrome trace-event JSON file (schema, per-lane
/// monotonicity, B/E pairing, counter events, span accounting against
/// each request's recorded end-to-end latency), `--series` checks a
/// JSONL metrics series (header schema + run metadata, consecutive
/// indices, non-decreasing timestamps), `--metrics` checks a metrics
/// snapshot JSON (parseability plus the approximation-quality audit
/// invariants of every `"quality"` block). All requested checks run —
/// a failure doesn't short-circuit the rest — then each reports
/// `PASS`/`FAIL` and the exit status is nonzero if any failed. Used by
/// the CI cluster-smoke job.
fn cmd_obs(args: &Args) -> anyhow::Result<()> {
    let check_trace = |path: &str| -> Result<String, String> {
        let text = std::fs::read_to_string(path).map_err(|e| e.to_string())?;
        let doc = wildcat::util::json::parse(&text)?;
        let s = wildcat::obs::validate_chrome_trace(&doc)?;
        Ok(format!(
            "{} event(s), {} span(s), {} counter sample(s), {} lane(s), \
             {} retired request(s), {} dropped, max accounting error {:.2}%",
            s.events,
            s.spans,
            s.counters,
            s.lanes,
            s.retired,
            s.dropped,
            100.0 * s.max_account_err
        ))
    };
    let check_series = |path: &str| -> Result<String, String> {
        let text = std::fs::read_to_string(path).map_err(|e| e.to_string())?;
        let s = wildcat::obs::validate_series(&text)?;
        Ok(format!("{} sample(s) at {} ms interval", s.samples, s.interval_ms))
    };
    let check_metrics = |path: &str| -> Result<String, String> {
        let text = std::fs::read_to_string(path).map_err(|e| e.to_string())?;
        let doc = wildcat::util::json::parse(&text)?;
        let n = wildcat::obs::validate_quality_json(&doc)?;
        Ok(match n {
            0 => "parses; no quality block (auditing off)".to_string(),
            n => format!("parses; {n} quality block(s) satisfy the audit invariants"),
        })
    };
    // run every requested check — a corrupt trace must not hide a
    // truncated series from the report
    let mut results: Vec<(&str, String, Result<String, String>)> = Vec::new();
    if let Some(path) = args.get("trace") {
        results.push(("trace", path.to_string(), check_trace(path)));
    }
    if let Some(path) = args.get("series") {
        results.push(("series", path.to_string(), check_series(path)));
    }
    if let Some(path) = args.get("metrics") {
        results.push(("metrics", path.to_string(), check_metrics(path)));
    }
    anyhow::ensure!(
        !results.is_empty(),
        "nothing to validate: pass --trace, --series and/or --metrics"
    );
    let mut failed = 0;
    for (kind, path, res) in &results {
        match res {
            Ok(detail) => println!("PASS {kind} {path}: {detail}"),
            Err(e) => {
                eprintln!("FAIL {kind} {path}: {e}");
                failed += 1;
            }
        }
    }
    anyhow::ensure!(failed == 0, "{failed} of {} obs check(s) failed", results.len());
    println!("obs: all {} check(s) passed", results.len());
    Ok(())
}

fn print_pool_line(prefix: &str, s: &PoolSnapshot) {
    println!(
        "{prefix}kv pool: used {:.2} MiB (peak {:.2} MiB), prefix hit rate {:.0}%, \
         tier compressions {}, evicted blocks {}, admission rejects {}",
        s.used_bytes() as f64 / (1024.0 * 1024.0),
        s.peak_bytes() as f64 / (1024.0 * 1024.0),
        100.0 * s.prefix_hit_rate(),
        s.tier_compressions,
        s.evicted_blocks,
        s.admission_rejects,
    );
    // only spill-armed runs print a spill line (bit-identical output off)
    if let Some(sp) = &s.spill {
        println!(
            "{prefix}spill: {} block(s) spilled ({:.2} MiB written), {} page-in(s) \
             ({} tokens), {} cold eviction(s), {} corrupt record(s)",
            sp.spills,
            sp.spill_bytes as f64 / (1024.0 * 1024.0),
            sp.page_ins,
            sp.pagein_tokens,
            sp.spill_evictions,
            sp.spill_corrupt,
        );
    }
}

/// `wildcat bench [--smoke] [--out DIR] [--only fig3,table4,...] [--seed N]`
///
/// Runs the paper benches through the shared runners in
/// `wildcat::bench::runners` and writes one `BENCH_<id>.json` per bench
/// into `--out` (default: the current directory, i.e. the repo root when
/// invoked from a checkout). `--smoke` is the CI contract: the full suite
/// in well under two minutes on four cores, deterministic for a given
/// `--seed`.
fn cmd_bench(args: &Args) -> anyhow::Result<()> {
    let cfg = wildcat::bench::RunCfg::from_args(args);
    let out_dir = args.get_or("out", ".");
    let only = args.get("only");
    let written = wildcat::bench::run_all(&cfg, std::path::Path::new(&out_dir), only)?;
    for p in &written {
        // re-read + validate what landed on disk: the CI job greps this
        let text = std::fs::read_to_string(p)?;
        wildcat::bench::report::validate_str(&text)
            .map_err(|e| anyhow::anyhow!("{}: {e}", p.display()))?;
    }
    println!("[bench] all {} report(s) validate against the schema", written.len());
    Ok(())
}

fn cmd_info(args: &Args) -> anyhow::Result<()> {
    let dir = args.get_or("artifacts", "artifacts");
    let rt = wildcat::runtime::PjrtRuntime::open(&dir)?;
    println!("platform: {}", rt.platform());
    println!("model: {:?}", rt.manifest.model);
    println!("artifacts ({}):", rt.manifest.artifacts.len());
    for a in &rt.manifest.artifacts {
        println!("  {:<28} {} inputs, {} outputs", a.name, a.inputs.len(), a.outputs.len());
    }
    Ok(())
}

/// `wildcat cluster --replicas N --policy P [--rate R --duration D]
/// [--shape stationary|onoff|gamma] [--fast] [--metrics-json PATH]
/// [--kv-budget-mb MB --prefix-sharing on|off --prefill-skip on|off]
/// [--spill-budget-mb MB --spill-dir PATH]
/// [--audit-rate N --audit-slo-abs-err E]
/// [--request-timeout-ms N --max-retries N --supervise-interval-ms N]
/// [--fault-seed S --fault-crash-every N --fault-stall-every N
/// --fault-stall-ms MS --fault-reject-every N]
/// [--trace-json PATH --trace-capacity N] [--metrics-series PATH
/// --metrics-interval-ms N] [--prom PATH]`
///
/// Spawns a replica pool behind the chosen routing policy and replays a
/// synthetic trace against it — at wall-clock rate by default, or in
/// virtual time with `--fast` (the CI smoke path). Uses the trained
/// model when `artifacts/weights.bin` exists, else a seeded random model
/// so the command works on a bare checkout.
///
/// The `--fault-*` flags arm a deterministic [`FaultPlan`] (crashes,
/// stalls, transient rejects) for chaos runs; all default to 0 = off, and
/// a fault-free run carries no fault plumbing on the hot path (see
/// docs/ROBUSTNESS.md).
fn cmd_cluster(args: &Args) -> anyhow::Result<()> {
    let seed = args.get_parse::<u64>("seed", 0);
    let n_replicas = args.get_parse::<usize>("replicas", 4);
    let policy = RoutingPolicy::parse(&args.get_or("policy", "join_shortest_queue"))?;
    let rate = args.get_parse::<f64>("rate", 8.0);
    let secs = args.get_parse::<f64>("duration", 5.0);
    let budget = args.get_parse::<usize>("budget", 96);
    let queue_cap = args.get_parse::<usize>("queue-cap", 64);
    let fast = args.flag("fast");
    let shape = TraceShape::parse(&args.get_or("shape", "stationary"))?;
    let compressor = compressor_by_name(&args.get_or("compressor", "compresskv"))?;
    let request_timeout_ms = args.get_parse::<u64>("request-timeout-ms", 0);
    let max_retries = args.get_parse::<u32>("max-retries", 2);
    let fault_cfg = FaultConfig {
        seed: args.get_parse::<u64>("fault-seed", seed),
        crash_every: args.get_parse::<u64>("fault-crash-every", 0),
        stall_every: args.get_parse::<u64>("fault-stall-every", 0),
        stall_ms: args.get_parse::<u64>("fault-stall-ms", 0),
        reject_every: args.get_parse::<u64>("fault-reject-every", 0),
    };
    // None when every knob is 0: fault-free runs carry no plan at all
    let faults = FaultPlan::new(fault_cfg, n_replicas.max(1));

    let mut cfg = ServerConfig::default();
    cfg.queue_capacity = queue_cap;
    cfg.scheduler.cache_budget = budget;
    cfg.scheduler.prefill_skip = prefill_skip_from_args(args)?;
    cfg.pool = pool_config_from_args(args)?;
    cfg.seed = seed;
    cfg.quality = quality_config_from_args(args, seed);
    cfg.faults = faults.clone();

    let run = obs::run_meta(
        "cluster",
        seed,
        vec![
            ("replicas", Json::Num(n_replicas as f64)),
            ("policy", Json::Str(policy.name().to_string())),
            ("rate", Json::Num(rate)),
            ("duration_s", Json::Num(secs)),
            ("shape", Json::Str(shape.name().to_string())),
            ("fast", Json::Bool(fast)),
            ("cache_budget", Json::Num(budget as f64)),
            ("queue_cap", Json::Num(queue_cap as f64)),
            ("kv_budget_mb", Json::Num(args.get_parse::<f64>("kv-budget-mb", 0.0))),
            ("spill_budget_mb", Json::Num(args.get_parse::<f64>("spill-budget-mb", 0.0))),
            ("prefix_sharing", Json::Bool(cfg.pool.prefix_sharing)),
            ("prefill_skip", Json::Bool(cfg.scheduler.prefill_skip)),
            ("compressor", Json::Str(args.get_or("compressor", "compresskv"))),
            ("audit_rate", Json::Num(cfg.quality.rate as f64)),
            ("audit_slo_abs_err", Json::Num(cfg.quality.slo_abs_err)),
            ("request_timeout_ms", Json::Num(request_timeout_ms as f64)),
            ("max_retries", Json::Num(max_retries as f64)),
            ("faults_armed", Json::Bool(faults.is_some())),
        ],
    );
    // enable tracing before the replicas spawn so startup spans land too
    let trace_path = trace_setup(args);

    let model_cfg = ModelConfig::default();
    // the cluster CLI always works on a bare checkout: fall back (with
    // the underlying load error surfaced) to a seeded random model
    let weights = wildcat::bench::runners::load_weights(args, true, "cluster")?;
    let pool = Arc::new(ReplicaPool::spawn(
        n_replicas,
        cfg,
        compressor,
        wildcat::bench::runners::replica_backend_factory(weights, model_cfg, seed),
    ));
    let router = Arc::new(Router::new(
        pool.clone(),
        RouterConfig {
            policy,
            request_timeout: Duration::from_millis(request_timeout_ms),
            max_retries,
            seed,
            ..Default::default()
        },
    ));
    // dedicated supervision thread: crashed replicas are respawned even
    // when no traffic routes to them (the router only supervises the
    // replicas a request happens to touch)
    let supervise_ms = args.get_parse::<u64>("supervise-interval-ms", 5);
    let supervisor =
        Supervisor::start(pool.clone(), Clock::wall(), Duration::from_millis(supervise_ms.max(1)));
    let sampler = {
        let r = Arc::clone(&router);
        sampler_setup(args, &run, move || r.metrics_json())?
    };

    let mut rng = Rng::seed_from(seed);
    let trace = shaped_trace(&mut rng, rate, Duration::from_secs_f64(secs), &shape, 16, 96, 8);
    println!(
        "[cluster] {} replica(s), policy {}, replaying {} arrivals ({} shape, {})...",
        pool.len(),
        policy.name(),
        trace.len(),
        shape.name(),
        if fast { "virtual time" } else { "wall clock" }
    );
    let rcfg = ReplayConfig {
        pacing: if fast { Pacing::Virtual } else { Pacing::WallClock },
        vocab: model_cfg.vocab as u32,
        ..Default::default()
    };
    let stats = replay(&router, &trace, &rcfg, &mut rng);
    println!(
        "requests: submitted={} completed={} rejected={} deadline-exceeded={} (reject rate {:.1}%)\n\
         throughput: {:.1} req/s, {:.1} tok/s\n\
         e2e latency: p50 {:.2} ms  p95 {:.2} ms  p99 {:.2} ms",
        stats.submitted,
        stats.completed,
        stats.rejected,
        stats.deadline_exceeded,
        100.0 * stats.reject_rate,
        stats.throughput_rps,
        stats.tokens_per_s,
        stats.p50_ms,
        stats.p95_ms,
        stats.p99_ms,
    );
    let snap = router.snapshot();
    if let Some(plan) = &faults {
        println!(
            "chaos: crashes={} stalls={} injected-rejects={} restarts={} failovers={} retries={}",
            plan.crashes(),
            plan.stalls(),
            plan.injected_rejects(),
            snap.restarts,
            snap.failovers,
            snap.retries,
        );
    }
    print_pool_line("", &router.pool_aggregate());
    // final series sample is written at stop, after every response has
    // been received: its counters equal the --metrics-json snapshot
    sampler_finish(args, sampler)?;
    let mut snapshot = match router.metrics_json() {
        Json::Obj(o) => o,
        _ => unreachable!("cluster metrics snapshot is always an object"),
    };
    snapshot.insert("run".to_string(), run);
    // only armed runs carry a fault block: a fault-free snapshot is
    // bit-identical to one from a build without the fault plane
    if let Some(plan) = &faults {
        snapshot.insert("faults".to_string(), plan.to_json());
    }
    if let Some(path) = args.get("metrics-json") {
        std::fs::write(path, Json::Obj(snapshot).to_string_compact())?;
        println!("cluster metrics snapshot written to {path}");
    }
    if let Some(path) = args.get("prom") {
        std::fs::write(path, router.to_prometheus())?;
        println!("prometheus exposition written to {path}");
    }
    // stop supervision before the replicas are torn down so a mid-shutdown
    // sweep can't race a slot whose handle is being taken
    supervisor.stop();
    pool.shutdown();
    if let Some(path) = trace_path {
        trace_finish(&path)?;
    }
    Ok(())
}

/// `wildcat serve [--rate R --secs S --budget B] [--pjrt]
/// [--kv-budget-mb MB --prefix-sharing on|off --prefill-skip on|off]
/// [--spill-budget-mb MB --spill-dir PATH]
/// [--audit-rate N --audit-slo-abs-err E]
/// [--metrics-json PATH] [--trace-json PATH --trace-capacity N]
/// [--metrics-series PATH --metrics-interval-ms N] [--prom PATH]`
fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    let seed = args.get_parse::<u64>("seed", 0);
    let rate = args.get_parse::<f64>("rate", 4.0);
    let secs = args.get_parse::<u64>("secs", 5);
    let budget = args.get_parse::<usize>("budget", 96);
    let use_pjrt = args.flag("pjrt");
    let compressor = compressor_by_name(&args.get_or("compressor", "compresskv"))?;
    let artifacts = args.get_or("artifacts", "artifacts");

    let mut cfg = ServerConfig::default();
    cfg.scheduler.cache_budget = budget;
    cfg.scheduler.prefill_skip = prefill_skip_from_args(args)?;
    cfg.pool = pool_config_from_args(args)?;
    cfg.seed = seed;
    cfg.quality = quality_config_from_args(args, seed);

    let run = obs::run_meta(
        "serve",
        seed,
        vec![
            ("rate", Json::Num(rate)),
            ("duration_s", Json::Num(secs as f64)),
            ("cache_budget", Json::Num(budget as f64)),
            ("backend", Json::Str(if use_pjrt { "pjrt" } else { "native" }.to_string())),
            ("kv_budget_mb", Json::Num(args.get_parse::<f64>("kv-budget-mb", 0.0))),
            ("spill_budget_mb", Json::Num(args.get_parse::<f64>("spill-budget-mb", 0.0))),
            ("prefix_sharing", Json::Bool(cfg.pool.prefix_sharing)),
            ("prefill_skip", Json::Bool(cfg.scheduler.prefill_skip)),
            ("compressor", Json::Str(args.get_or("compressor", "compresskv"))),
            ("audit_rate", Json::Num(cfg.quality.rate as f64)),
            ("audit_slo_abs_err", Json::Num(cfg.quality.slo_abs_err)),
        ],
    );
    let trace_path = trace_setup(args);

    let handle = if use_pjrt {
        let dir = artifacts.clone();
        Server::spawn(cfg, compressor, move || {
            wildcat::runtime::PjrtBackend::open(&dir).expect("open artifacts")
        })
    } else {
        let dir = artifacts.clone();
        Server::spawn(cfg, compressor, move || {
            let w = wildcat::model::WeightFile::load(format!("{dir}/weights.bin"))
                .expect("weights.bin (run `make artifacts`)");
            Transformer::from_weights(&w, ModelConfig::default()).expect("model")
        })
    };

    let sampler = {
        let client = handle.client();
        sampler_setup(args, &run, move || {
            let mut o = match client.metrics().to_json() {
                Json::Obj(o) => o,
                _ => std::collections::BTreeMap::new(),
            };
            o.insert("kv_pool".to_string(), client.pool_snapshot().to_json());
            Json::Obj(o)
        })?
    };

    let mut rng = Rng::seed_from(seed);
    let trace = poisson_trace(&mut rng, rate, Duration::from_secs(secs), 32, 200, 8);
    println!("replaying {} arrivals over {secs}s (rate {rate}/s)...", trace.len());
    let start = Instant::now();
    let mut rxs = Vec::new();
    for a in &trace {
        let now = start.elapsed();
        if a.at > now {
            std::thread::sleep(a.at - now);
        }
        let prompt: Vec<u32> = (0..a.prompt_len).map(|_| 6 + rng.below(58) as u32).collect();
        match handle.submit(prompt, a.max_new) {
            Ok((_, rx)) => rxs.push(rx),
            Err(e) => println!("rejected: {e:?}"),
        }
    }
    for rx in rxs {
        let _ = rx.recv_timeout(Duration::from_secs(300));
    }
    println!("{}", handle.metrics().report());
    print_pool_line("", &handle.client().pool_snapshot());
    sampler_finish(args, sampler)?;
    if let Some(path) = args.get("metrics-json") {
        // serving metrics plus the pool gauges in one document
        let mut snap = match handle.metrics().to_json() {
            Json::Obj(o) => o,
            _ => unreachable!("metrics snapshot is always an object"),
        };
        snap.insert("kv_pool".to_string(), handle.client().pool_snapshot().to_json());
        snap.insert("run".to_string(), run);
        let doc = Json::Obj(snap);
        std::fs::write(path, doc.to_string_compact())?;
        println!("metrics snapshot written to {path}");
    }
    if let Some(path) = args.get("prom") {
        let mut b = wildcat::obs::PromBuilder::new();
        handle.metrics().prom_write(&mut b, &[]);
        handle.client().pool_snapshot().prom_write(&mut b, &[]);
        std::fs::write(path, b.finish())?;
        println!("prometheus exposition written to {path}");
    }
    handle.shutdown();
    if let Some(path) = trace_path {
        trace_finish(&path)?;
    }
    Ok(())
}

fn cmd_attn(args: &Args) -> anyhow::Result<()> {
    let n = args.get_parse::<usize>("n", 4096);
    let d = args.get_parse::<usize>("d", 64);
    let rank = args.get_parse::<usize>("rank", 64);
    let bins = args.get_parse::<usize>("bins", 16);
    let mut rng = Rng::seed_from(args.get_parse::<u64>("seed", 0));
    let w = gaussian_qkv(&mut rng, n, n, d, d);
    let t0 = Instant::now();
    let exact = exact_attention(&w.q, &w.k, &w.v, w.beta);
    let t_exact = t0.elapsed();
    let params = WildcatParams { rank, bins, beta: Some(w.beta as f64) };
    let t1 = Instant::now();
    let approx = wildcat_attention(&w.q, &w.k, &w.v, &params, &mut rng);
    let t_wc = t1.elapsed();
    println!(
        "n={n} d={d} r={rank} B={bins}: exact {:.1} ms, wildcat {:.1} ms ({:.2}x), err_max = {:.3e}",
        t_exact.as_secs_f64() * 1e3,
        t_wc.as_secs_f64() * 1e3,
        t_exact.as_secs_f64() / t_wc.as_secs_f64(),
        max_abs_diff(&approx, &exact)
    );
    Ok(())
}

fn cmd_tasks(args: &Args) -> anyhow::Result<()> {
    let dir = args.get_or("artifacts", "artifacts");
    let budget = args.get_parse::<usize>("budget", 96);
    let n_ctx = args.get_parse::<usize>("context", 256);
    let trials = args.get_parse::<usize>("trials", 10);
    let compressor = compressor_by_name(&args.get_or("compressor", "compresskv"))?;
    let w = wildcat::model::WeightFile::load(format!("{dir}/weights.bin"))?;
    let model = Transformer::from_weights(&w, ModelConfig::default())?;
    let mut rng = Rng::seed_from(args.get_parse::<u64>("seed", 0));
    println!("task scores (budget {budget}, context {n_ctx}):");
    let mut total = 0.0;
    for task in task_suite() {
        let mut s = 0.0;
        for _ in 0..trials {
            let inst = task.kind.generate(&mut rng, n_ctx, model.cfg.vocab as u32);
            let out = wildcat::model::generate::greedy_decode_with_query(
                &model,
                &inst.context,
                &inst.query,
                inst.expected.len(),
                budget,
                compressor.as_ref(),
                &mut rng,
            );
            s += wildcat::workload::tasks::score(&inst.expected, &out.tokens);
        }
        let s = 100.0 * s / trials as f64;
        total += s;
        println!("  {:<12} {:>6.2}", task.name, s);
    }
    println!("  {:<12} {:>6.2}", "average", total / 13.0);
    Ok(())
}
