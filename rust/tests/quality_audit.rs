//! Approximation-quality auditing integration tests — the acceptance
//! contract of the online auditor:
//!
//! * a breaching error SLO produces exactly one degrade transition
//!   (tracer span + counter) and, once the window drains below the
//!   hysteresis threshold, exactly one recovery;
//! * a seeded audited serve run reports the *same* p99 audited error
//!   across every export surface (snapshot, metrics JSON, Prometheus,
//!   metrics series);
//! * `--audit-rate 0` leaves every surface free of quality metrics;
//! * for every compression policy, the audited fold error equals an
//!   offline recompute from the same pre-fold rows (same seed ⇒
//!   identical sites ⇒ identical errors), and reruns are bit-identical;
//! * the `wildcat obs` CLI runs every requested check, reports
//!   per-check PASS/FAIL, and exits nonzero when any artifact is bad.
//!
//! Tests touching the process-wide tracer serialize on a lock (this
//! binary's tests run concurrently on threads).

use std::sync::{Arc, Mutex, MutexGuard};
use std::time::Duration;
use wildcat::coordinator::{Server, ServerConfig, ServerHandle};
use wildcat::kvcache::{
    compressor_by_name, CompressionCtx, KvCompressor, StreamingLlm, COMPRESSOR_NAMES,
};
use wildcat::kvpool::{KvPool, KvPoolConfig};
use wildcat::model::{ModelConfig, Transformer};
use wildcat::obs::quality::{self, slo};
use wildcat::obs::trace::{self, SpanKind};
use wildcat::obs::{
    MetricsSampler, PromBuilder, QualityAudit, QualityConfig, QualitySnapshot,
};
use wildcat::rng::Rng;
use wildcat::util::json::Json;

static GLOBAL_TRACER_LOCK: Mutex<()> = Mutex::new(());

fn lock_global() -> MutexGuard<'static, ()> {
    GLOBAL_TRACER_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn tiny_model(seed: u64) -> Transformer {
    let mcfg =
        ModelConfig { vocab: 16, d_model: 16, n_layers: 2, n_heads: 2, d_ff: 32, max_len: 256 };
    Transformer::random(mcfg, &mut Rng::seed_from(seed))
}

fn audited_server(rate: u32, cache_budget: usize) -> ServerHandle {
    let mut cfg = ServerConfig::default();
    cfg.scheduler.cache_budget = cache_budget;
    cfg.quality = QualityConfig { rate, slo_abs_err: 0.0, seed: 11 };
    Server::spawn(cfg, Arc::new(StreamingLlm), || tiny_model(13))
}

#[test]
fn slo_breach_degrades_once_then_recovers_once_with_spans() {
    let _g = lock_global();
    let tracer = trace::global();
    tracer.enable_with_capacity(16_384);

    let audit =
        QualityAudit::new(QualityConfig { rate: 1, slo_abs_err: 1e-3, seed: 0 });
    // a full window of breaching errors: the state machine must fire
    // exactly one degrade transition, not one per breaching sample
    for _ in 0..slo::WINDOW {
        audit.observe_fold(0, 0, 5e-3, 1e-2);
    }
    assert!(audit.is_degraded(), "windowed p99 over the SLO must degrade");
    // errors drain below the hysteresis threshold: exactly one recovery
    for _ in 0..2 * slo::WINDOW {
        audit.observe_fold(0, 0, 1e-6, 1e-6);
    }
    assert!(!audit.is_degraded(), "low window must recover");
    let s = audit.snapshot();
    assert_eq!((s.degradations, s.recoveries), (1, 1), "hysteresis: one transition each way");

    tracer.set_enabled(false);
    let buf = tracer.drain();
    let transitions: Vec<_> =
        buf.events.iter().filter(|e| e.kind == SpanKind::SloTransition).collect();
    assert_eq!(transitions.len(), 2, "one span per SLO transition");
    assert_eq!(transitions[0].a, 1, "first transition is a degrade");
    assert_eq!(transitions[1].a, 0, "second transition is a recovery");
    assert!(transitions[0].b > 0, "degrade span carries the breaching window p99");
    // every audited sample also left a quality span with its error payload
    let quality_spans =
        buf.events.iter().filter(|e| e.kind == SpanKind::Quality).count();
    assert_eq!(quality_spans as u64, s.audited_folds);
}

#[test]
fn audited_serve_reports_one_p99_across_every_surface() {
    // hold the tracer lock: audited decodes would otherwise record
    // quality spans into the ring while the SLO test has it enabled
    let _g = lock_global();
    // budget 24 against 40-token prompts: compression fires, so the
    // audited error is nonzero and a cross-surface mismatch can't hide
    // behind zeros
    let handle = audited_server(1, 24);
    let mut rng = Rng::seed_from(3);

    let dir = std::env::temp_dir().join(format!("wildcat_quality_it_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let series_path = dir.join("series.jsonl");
    let client = handle.client();
    let run = wildcat::obs::run_meta("test-audit", 11, vec![("audit_rate", Json::Num(1.0))]);
    let sampler =
        MetricsSampler::start(&series_path, run, Duration::from_millis(20), move || {
            client.metrics().to_json()
        })
        .unwrap();

    let mut rxs = Vec::new();
    for _ in 0..5 {
        let prompt: Vec<u32> = (0..40).map(|_| 2 + rng.below(12) as u32).collect();
        let (_, rx) = handle.submit(prompt, 4).unwrap();
        rxs.push(rx);
    }
    for rx in rxs {
        assert!(rx.recv_timeout(Duration::from_secs(60)).is_ok());
    }
    // all responses received: the audit statistics are final, so every
    // surface below renders the same snapshot
    sampler.stop().unwrap();
    let snap = handle.metrics().quality_snapshot().expect("audit attached");
    assert!(snap.audited_decode > 0, "rate-1 audit must sample decode steps");
    assert!(snap.err_p99 > 0.0, "compressed serving must show nonzero audited error");

    // metrics JSON
    let json = handle.metrics().to_json();
    let q = json.get("quality").expect("quality block in metrics JSON");
    assert_eq!(q.get("max_abs_err_p99").and_then(Json::as_f64), Some(snap.err_p99));
    assert_eq!(
        q.get("audited_samples").and_then(Json::as_f64),
        Some((snap.audited_decode + snap.audited_folds) as f64)
    );

    // Prometheus exposition
    let mut b = PromBuilder::new();
    handle.metrics().prom_write(&mut b, &[]);
    let prom = b.finish();
    let line = prom
        .lines()
        .find(|l| l.starts_with("wildcat_quality_max_abs_err{quantile=\"0.99\"}"))
        .expect("p99 sample in prom exposition");
    let prom_p99: f64 = line.rsplit(' ').next().unwrap().parse().unwrap();
    assert_eq!(prom_p99, snap.err_p99, "prom and snapshot disagree:\n{prom}");

    // metrics series: the final sample carries the same quality block
    let text = std::fs::read_to_string(&series_path).unwrap();
    wildcat::obs::validate_series(&text).expect("series must validate");
    let last = wildcat::util::json::parse(
        text.lines().filter(|l| !l.trim().is_empty()).last().unwrap(),
    )
    .unwrap();
    let sq = last.get("quality").expect("quality block in final series sample");
    assert_eq!(sq.get("max_abs_err_p99").and_then(Json::as_f64), Some(snap.err_p99));

    handle.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn rate_zero_leaves_every_surface_clean() {
    let _g = lock_global();
    let handle = audited_server(0, 96);
    let (_, rx) = handle.submit(vec![2, 3, 4, 5], 2).unwrap();
    assert!(rx.recv_timeout(Duration::from_secs(60)).is_ok());
    assert!(handle.metrics().quality_snapshot().is_none());
    assert!(handle.metrics().to_json().get("quality").is_none());
    let mut b = PromBuilder::new();
    handle.metrics().prom_write(&mut b, &[]);
    assert!(!b.finish().contains("wildcat_quality_"));
    assert!(!handle.metrics().report().contains("quality:"));
    handle.shutdown();
}

/// Drive one seeded pool workload to a compression fold under a rate-1
/// auditor; returns the audit snapshot plus the offline per-fold
/// `max_abs_err` recomputed from the same pre-fold rows, compressor, and
/// rng seed.
fn audited_fold_run(name: &str, seed: u64) -> (QualitySnapshot, Vec<f64>) {
    let comp = compressor_by_name(name).unwrap();
    let pool = KvPool::new(KvPoolConfig::default(), comp.clone());
    let audit =
        Arc::new(QualityAudit::new(QualityConfig { rate: 1, slo_abs_err: 0.0, seed }));
    pool.set_quality_audit(audit.clone());
    let (n_lh, d, rows, budget) = (2usize, 8usize, 128usize, 80usize);
    pool.create_sequence(1, n_lh, d, d);
    let mut rng = Rng::seed_from(seed ^ 0xABCD);
    for _ in 0..rows {
        for lh in 0..n_lh {
            let k: Vec<f32> = (0..d).map(|_| rng.gaussian() as f32).collect();
            let v: Vec<f32> = (0..d).map(|_| rng.gaussian() as f32).collect();
            pool.append_row(1, lh, &k, &v);
        }
    }
    // snapshot the pre-fold rows through the same gather the fold audit
    // sees, *before* compressing folds them away
    let pre: Vec<_> = (0..n_lh).map(|lh| pool.layer_view(1, lh).unwrap()).collect();
    let mut crng = Rng::seed_from(77);
    assert_eq!(pool.compress_sequence(1, budget, None, &mut crng), n_lh);
    // offline recompute: identical compressor + rng seed + probe seed,
    // fold index f = lh (every layer-head folded once, in order)
    let mut orng = Rng::seed_from(77);
    let mut expected = Vec::new();
    for (lh, (k, v, w, _)) in pre.iter().enumerate() {
        let ctx = CompressionCtx {
            keys: k,
            values: v,
            budget,
            beta: 0.35,
            layer: lh,
            n_layers: n_lh,
            obs_queries: None,
        };
        let e = comp.compress(&ctx, &mut orng);
        let probe = quality::probe_queries(seed, 1, lh as u64, d);
        let (max_abs, _) = quality::fold_error(&probe, k, v, w, &e, 0.35f32);
        expected.push(max_abs);
    }
    (audit.snapshot(), expected)
}

#[test]
fn fold_audit_matches_offline_recompute_for_every_compressor() {
    let _g = lock_global();
    for name in COMPRESSOR_NAMES {
        let (snap, expected) = audited_fold_run(name, 5);
        assert_eq!(snap.audited_folds, 2, "{name}: rate 1 must audit every fold");
        assert_eq!(snap.audited_decode, 0);
        let exp_max = expected.iter().cloned().fold(0.0f64, f64::max);
        let exp_sum: f64 = expected.iter().sum();
        // bit-exact: the audit computed the same reference from the same
        // rows with the same probes
        assert_eq!(snap.err_max, exp_max, "{name}: audited max != offline recompute");
        assert_eq!(snap.err_sum, exp_sum, "{name}: audited sum != offline recompute");
        // determinism: a rerun with the same seed audits identical sites
        // and produces identical errors
        let (again, _) = audited_fold_run(name, 5);
        assert_eq!(snap.err_max, again.err_max, "{name}: rerun changed err_max");
        assert_eq!(snap.err_sum, again.err_sum, "{name}: rerun changed err_sum");
        assert_eq!(snap.err_count, again.err_count, "{name}: rerun changed err_count");
        // a different seed picks different probes: the audit is actually
        // seed-dependent, not constant (skip policies that reproduce the
        // rows exactly, where every probe reads zero error)
        if exp_max > 0.0 {
            let (other, _) = audited_fold_run(name, 6);
            assert_ne!(snap.err_max, other.err_max, "{name}: probe seed has no effect");
        }
    }
}

#[test]
fn obs_cli_runs_every_check_and_exits_nonzero_on_failure() {
    let dir = std::env::temp_dir().join(format!("wildcat_obs_cli_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let bad_trace = dir.join("bad_trace.json");
    let bad_series = dir.join("bad_series.jsonl");
    let good_metrics = dir.join("metrics.json");
    // corrupted trace: truncated mid-document, not valid JSON
    std::fs::write(&bad_trace, "{\"traceEvents\":[{\"ph\":\"B\",").unwrap();
    // truncated series: a header that promises samples, then garbage
    std::fs::write(&bad_series, "{\"schema\":\"wildcat.series.v1\"}\n{\"index\":").unwrap();
    // a valid metrics snapshot without a quality block still passes
    std::fs::write(&good_metrics, "{\"completed\":3}").unwrap();

    let bin = env!("CARGO_BIN_EXE_wildcat");
    let out = std::process::Command::new(bin)
        .args([
            "obs",
            "--trace",
            bad_trace.to_str().unwrap(),
            "--series",
            bad_series.to_str().unwrap(),
            "--metrics",
            good_metrics.to_str().unwrap(),
        ])
        .output()
        .expect("spawn wildcat obs");
    assert!(!out.status.success(), "bad artifacts must exit nonzero");
    let stderr = String::from_utf8_lossy(&out.stderr);
    let stdout = String::from_utf8_lossy(&out.stdout);
    // per-check summary: both failures named on stderr, the passing
    // check still ran and reported on stdout
    assert!(stderr.contains("FAIL trace"), "stderr:\n{stderr}");
    assert!(stderr.contains("FAIL series"), "stderr:\n{stderr}");
    assert!(stderr.contains("2 of 3 obs check(s) failed"), "stderr:\n{stderr}");
    assert!(stdout.contains("PASS metrics"), "stdout:\n{stdout}");

    // all-good invocation exits zero with a per-check PASS summary
    let ok = std::process::Command::new(bin)
        .args(["obs", "--metrics", good_metrics.to_str().unwrap()])
        .output()
        .expect("spawn wildcat obs");
    assert!(ok.status.success(), "stderr:\n{}", String::from_utf8_lossy(&ok.stderr));
    let stdout = String::from_utf8_lossy(&ok.stdout);
    assert!(stdout.contains("PASS metrics"), "stdout:\n{stdout}");
    assert!(stdout.contains("all 1 check(s) passed"), "stdout:\n{stdout}");
    let _ = std::fs::remove_dir_all(&dir);
}
