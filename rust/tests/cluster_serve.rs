//! Cluster-tier integration tests — the PR-2 acceptance contract:
//!
//! * on one fixed-seed trace replayed in virtual time, a 4-replica
//!   `join_shortest_queue` cluster achieves strictly higher throughput
//!   than a single replica, with a strictly lower reject rate;
//! * the `serve` bench (part of `wildcat bench --smoke`) writes a
//!   schema-valid `BENCH_serve.json` covering every routing policy at
//!   1 vs N replicas.

use std::sync::Arc;
use std::time::Duration;
use wildcat::cluster::{
    replay, Pacing, ReplayConfig, ReplayStats, ReplicaPool, Router, RouterConfig, RoutingPolicy,
};
use wildcat::coordinator::{SchedulerConfig, ServerConfig};
use wildcat::kvcache::StreamingLlm;
use wildcat::model::{ModelConfig, Transformer};
use wildcat::rng::Rng;
use wildcat::workload::{shaped_trace, TraceShape};

fn run_cluster(n_replicas: usize, policy: RoutingPolicy, seed: u64) -> ReplayStats {
    let cfg = ServerConfig {
        // small per-replica admission queue so the virtual-time replay
        // (all arrivals back-to-back) saturates a single replica
        queue_capacity: 8,
        max_prompt: 128,
        scheduler: SchedulerConfig { cache_budget: 96, slack: 8, ..Default::default() },
        ..Default::default()
    };
    let pool = Arc::new(ReplicaPool::spawn(n_replicas, cfg, Arc::new(StreamingLlm), |i| {
        let mcfg = ModelConfig {
            vocab: 16,
            d_model: 16,
            n_layers: 2,
            n_heads: 2,
            d_ff: 32,
            max_len: 256,
        };
        Transformer::random(mcfg, &mut Rng::seed_from(7 + i as u64))
    }));
    let router = Router::new(pool.clone(), RouterConfig { policy, ..Default::default() });
    // the same fixed-seed bursty trace for every configuration
    let mut trace_rng = Rng::seed_from(seed);
    let shape = TraceShape::OnOff { period: Duration::from_millis(200), duty: 0.3, burst: 3.0 };
    let trace = shaped_trace(&mut trace_rng, 100.0, Duration::from_secs(1), &shape, 8, 32, 4);
    assert!(trace.len() > 50, "trace unexpectedly short: {}", trace.len());
    let rcfg = ReplayConfig {
        pacing: Pacing::Virtual,
        vocab: 16,
        n_sessions: 8,
        timeout: Duration::from_secs(120),
    };
    let mut prompt_rng = Rng::seed_from(seed + 1);
    let stats = replay(&router, &trace, &rcfg, &mut prompt_rng);
    pool.shutdown();
    stats
}

/// The acceptance criterion: scaling 1 → 4 replicas under
/// `join_shortest_queue` strictly raises throughput and strictly lowers
/// the reject rate on the same fixed-seed trace (virtual-time mode).
#[test]
fn four_jsq_replicas_beat_one_on_the_same_trace() {
    let one = run_cluster(1, RoutingPolicy::JoinShortestQueue, 42);
    let four = run_cluster(4, RoutingPolicy::JoinShortestQueue, 42);
    assert_eq!(one.submitted, four.submitted, "configs must replay the same trace");
    assert_eq!(one.timed_out, 0);
    assert_eq!(four.timed_out, 0);
    // the single replica must actually saturate, else the comparison is vacuous
    assert!(one.rejected > 0, "1-replica config did not saturate: {one:?}");
    assert!(
        four.throughput_rps > one.throughput_rps,
        "4-replica jsq not faster: {:.1} vs {:.1} req/s",
        four.throughput_rps,
        one.throughput_rps
    );
    assert!(
        four.reject_rate < one.reject_rate,
        "4-replica jsq rejects more: {:.3} vs {:.3}",
        four.reject_rate,
        one.reject_rate
    );
    assert!(four.completed > one.completed);
}

/// Re-routing keeps traffic flowing around saturated replicas: under the
/// same overload, a 2-replica round-robin cluster still answers every
/// accepted request and only rejects after every replica refused.
#[test]
fn rerouting_never_drops_requests_under_overload() {
    let stats = run_cluster(2, RoutingPolicy::RoundRobin, 11);
    assert_eq!(
        stats.completed + stats.rejected + stats.deadline_exceeded,
        stats.submitted,
        "arrivals lost: {stats:?}"
    );
    assert_eq!(stats.timed_out, 0);
    assert!(stats.completed > 0);
}

/// `wildcat bench --smoke` writes a schema-valid `BENCH_serve.json` with
/// one record per (policy, replica-count) configuration.
#[test]
fn serve_bench_smoke_writes_schema_valid_report() {
    use wildcat::bench::report::validate_str;
    use wildcat::bench::runners::{run_all, RunCfg};
    use wildcat::util::cli::Args;
    use wildcat::util::json::Json;

    let out = std::env::temp_dir().join(format!("wildcat_serve_smoke_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&out);
    std::fs::create_dir_all(&out).unwrap();

    // small trace override keeps the test seconds-scale
    let args = Args::parse(["--smoke", "--rate", "200", "--duration", "0.25"]);
    let cfg = RunCfg::from_args(&args);
    let written = run_all(&cfg, &out, Some("serve")).unwrap();
    assert_eq!(written.len(), 1);
    assert!(written[0].ends_with("BENCH_serve.json"));

    let text = std::fs::read_to_string(&written[0]).unwrap();
    let j = validate_str(&text).unwrap_or_else(|e| panic!("BENCH_serve.json invalid: {e}"));
    assert_eq!(j.get("bench").and_then(Json::as_str), Some("serve"));
    assert_eq!(j.get("mode").and_then(Json::as_str), Some("smoke"));
    let records = j.get("records").unwrap().as_arr().unwrap();
    // every policy at 1 and 4 replicas
    for policy in ["round_robin", "join_shortest_queue", "affinity"] {
        for n in [1usize, 4] {
            let name = format!("{policy} x{n}");
            let rec = records
                .iter()
                .find(|r| r.get("name").and_then(Json::as_str) == Some(name.as_str()))
                .unwrap_or_else(|| panic!("missing record {name:?}"));
            for field in ["throughput_rps", "tokens_per_s", "p95_ms", "p99_ms", "reject_rate"] {
                let v = rec
                    .get(field)
                    .and_then(Json::as_f64)
                    .unwrap_or_else(|| panic!("{name}: missing {field}"));
                assert!(v >= 0.0 && v.is_finite(), "{name}.{field} = {v}");
            }
            assert_eq!(rec.get("replicas").and_then(Json::as_f64), Some(n as f64));
        }
    }
    let _ = std::fs::remove_dir_all(&out);
}
