//! Integration tests for the `kvpool::spill` disk tier — the PR-10
//! acceptance contract:
//!
//! * with spill **off** (`spill: None`, the default) the pool snapshot
//!   carries no spill block and a fixed workload produces exactly the
//!   token streams of a spill-less build;
//! * with spill **on** under a tight float budget, the same workload
//!   completes with **zero** rejections, the evict tier spills cold
//!   prefix blocks to disk, repeat prompts page them back
//!   (`page_ins > 0`), and the served tokens are bit-identical to the
//!   spill-off run — the disk tier trades I/O for recompute, never
//!   accuracy;
//! * corrupt or torn on-disk records are detected by the integrity
//!   word, counted in `spill_corrupt`, and served as **misses**: the
//!   caller falls back to cold prefill and the pool ends up with the
//!   exact original rows, never garbage.

use std::sync::Arc;
use std::time::Duration;
use wildcat::coordinator::{SchedulerConfig, Server, ServerConfig};
use wildcat::kvcache::StreamingLlm;
use wildcat::kvpool::{spill_budget_bytes_from_mb, KvPool, KvPoolConfig, SpillParams};
use wildcat::linalg::Matrix;
use wildcat::model::{ModelConfig, Transformer};
use wildcat::rng::Rng;

fn tiny_model_cfg() -> ModelConfig {
    ModelConfig { vocab: 16, d_model: 16, n_layers: 2, n_heads: 2, d_ff: 32, max_len: 256 }
}

/// Run the fixed shared-root workload sequentially (submit, wait, next)
/// so the admission/eviction interleaving is deterministic. Returns the
/// per-request token streams plus the final pool snapshot.
///
/// Three rounds over four 40-token roots with a unique 8-token suffix
/// per request: round 1 populates the radix, the tight budget evicts
/// cold roots while other roots are active, and rounds 2-3 re-touch
/// every root after its eviction.
fn run_shared_root_workload(
    pool_cfg: KvPoolConfig,
) -> (Vec<Vec<u32>>, wildcat::kvpool::PoolSnapshot) {
    let cfg = ServerConfig {
        scheduler: SchedulerConfig { cache_budget: 1000, slack: 8, ..Default::default() },
        pool: pool_cfg,
        ..Default::default()
    };
    let mcfg = tiny_model_cfg();
    let server =
        Server::spawn(cfg, Arc::new(StreamingLlm), move || {
            Transformer::random(mcfg, &mut Rng::seed_from(7))
        });
    let mut streams = Vec::new();
    for round in 0..3u32 {
        for root in 0..4u32 {
            let mut prompt: Vec<u32> = (0..40).map(|j| (j + 5 * root) % 16).collect();
            let k = round * 4 + root; // globally unique suffix per request
            prompt.extend([k % 16; 8]);
            let (id, rx) = server.submit(prompt, 2).expect("admission queue accepts");
            let resp = rx.recv_timeout(Duration::from_secs(60)).expect("request served");
            assert_eq!(resp.id, id);
            streams.push(resp.tokens);
        }
    }
    let snap = server.client().pool_snapshot();
    let counters = server.metrics().counters();
    assert_eq!(counters.completed, 12, "every request must complete");
    assert_eq!(counters.rejected, 0, "the pressure ladder must absorb, not reject");
    server.shutdown();
    (streams, snap)
}

/// Spill-off runs are bit-identical to a spill-less build, and turning
/// spill on under the same tight budget changes memory traffic — spills
/// out, page-ins back — but not one served token.
#[test]
fn spill_tier_pages_back_evicted_roots_without_changing_tokens() {
    // one active 50-token sequence = 50 tokens * 4 lh * 17 floats; a
    // two-sequence budget holds the active request plus ~one cached
    // root, so older roots are evicted (and spilled) between rounds
    let tight = 2 * 50 * 4 * 17;
    let base = KvPoolConfig { budget_floats: tight, block_tokens: 8, ..Default::default() };

    let (off_streams, off_snap) = run_shared_root_workload(base.clone());
    assert!(off_snap.spill.is_none(), "spill: None must not grow a snapshot block");
    assert!(
        off_streams.iter().all(|t| t.len() == 2),
        "every request decodes its full budget"
    );

    let dir = std::env::temp_dir().join(format!("wildcat_spill_serve_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let on_cfg = KvPoolConfig {
        spill: Some(SpillParams {
            dir: dir.clone(),
            budget_bytes: spill_budget_bytes_from_mb(4.0),
            replica: 0,
        }),
        ..base
    };
    let (on_streams, on_snap) = run_shared_root_workload(on_cfg);
    assert_eq!(on_streams, off_streams, "the disk tier must never change served tokens");

    let sp = on_snap.spill.expect("spill configured");
    assert!(sp.spills > 0, "the tight budget must push evicted roots to disk");
    assert!(sp.page_ins > 0, "repeat roots must page back from the cold index");
    assert_eq!(sp.pagein_tokens % 8, 0, "page-ins are whole blocks");
    assert_eq!(sp.spill_corrupt, 0);
    assert_eq!(on_snap.admission_rejects, 0, "zero rejections with the disk rung in place");
    assert!(sp.used_bytes <= sp.budget_bytes, "cold index must hold its byte budget");
    let _ = std::fs::remove_dir_all(&dir);
}

/// Token stream whose KV rows are a deterministic function of the token
/// id, so exact row identity after a page-in or a fallback recompute is
/// checkable.
fn tagged_prefill(tokens: &[u32], n_lh: usize, d: usize) -> (Vec<Matrix>, Vec<Matrix>) {
    let mk = |scale: f32| {
        (0..n_lh)
            .map(|lh| {
                Matrix::from_fn(tokens.len(), d, |i, j| {
                    scale * (tokens[i] as f32 + lh as f32 * 1000.0 + j as f32 * 0.01)
                })
            })
            .collect::<Vec<_>>()
    };
    (mk(1.0), mk(-1.0))
}

/// Corrupt on-disk records are served as misses — the lookup falls back
/// to cold prefill, `spill_corrupt` counts the detection, and the pool
/// ends up with the exact original rows.
#[test]
fn corrupt_spill_records_fall_back_to_cold_prefill() {
    let dir = std::env::temp_dir().join(format!("wildcat_spill_corrupt_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let n = 32usize;
    let floats_per_seq = n * 2 * (4 + 4 + 1); // n_lh=2, d_k=d_v=4
    let cfg = KvPoolConfig {
        budget_floats: floats_per_seq,
        block_tokens: 8,
        spill: Some(SpillParams {
            dir: dir.clone(),
            budget_bytes: spill_budget_bytes_from_mb(4.0),
            replica: 0,
        }),
        ..Default::default()
    };
    let p = KvPool::new(cfg, Arc::new(StreamingLlm));
    let a: Vec<u32> = (0..n as u32).collect();
    let b: Vec<u32> = (0..n as u32).map(|t| t + 10_000).collect();
    let (ka, va) = tagged_prefill(&a, 2, 4);
    let (kb, vb) = tagged_prefill(&b, 2, 4);

    // budget fits one prompt: admitting B evicts (and spills) A's roots
    p.register_prefill(1, &a, &ka, &va).unwrap();
    p.drop_sequence(1);
    p.register_prefill(2, &b, &kb, &vb).unwrap();
    p.drop_sequence(2);
    p.register_prefill(3, &b, &kb, &vb).unwrap(); // keep B hot
    assert!(p.snapshot().spill.unwrap().spills > 0, "A's eviction must spill");

    // drain the writeback thread, then truncate every record on disk —
    // the shape a torn write leaves after a crash
    let store = p.spill_store().expect("spill configured");
    store.flush();
    let mut truncated = 0;
    for entry in std::fs::read_dir(&dir).unwrap() {
        let path = entry.unwrap().path();
        if path.extension().is_some_and(|e| e == "wcsp") {
            std::fs::OpenOptions::new().write(true).open(&path).unwrap().set_len(7).unwrap();
            truncated += 1;
        }
    }
    assert!(truncated > 0, "flush must have materialised the spilled records");

    // the damaged records must read as a miss, never as rows
    let h = p.lookup_prefix(&a);
    assert_eq!(h.matched_tokens(), 0, "corrupt records must page in nothing");
    p.release_prefix(h);
    let sp = p.snapshot().spill.unwrap();
    assert!(sp.spill_corrupt >= 1, "integrity failure must be counted");
    assert_eq!(sp.page_ins, 0);

    // fallback: the caller cold-prefills A from scratch and the pool
    // holds the exact original rows afterwards (B released first so the
    // one-sequence budget has an evictable tier to reclaim from)
    p.drop_sequence(3);
    let r = p.register_prefill(4, &a, &ka, &va).unwrap();
    assert_eq!(r.matched_tokens, 0, "nothing to resume from after the corruption");
    let layers = p.gather(4).expect("sequence registered");
    assert_eq!(layers.len(), 2);
    for (lh, (k, v, w)) in layers.iter().enumerate() {
        assert_eq!(k, &ka[lh], "fallback keys must match the original rows");
        assert_eq!(v, &va[lh], "fallback values must match the original rows");
        assert!(w.iter().all(|&x| x == 1.0), "cold prefill rows carry unit weights");
    }
    let _ = std::fs::remove_dir_all(&dir);
}
