//! PJRT round-trip integration tests: the AOT artifacts (python-lowered
//! HLO) executed through the Rust runtime must match the native Rust
//! implementations. Skips gracefully when `make artifacts` has not run.
//! The whole file is compiled only with the `pjrt` feature (the offline
//! build has no xla bindings; see rust/src/runtime/mod.rs).
#![cfg(feature = "pjrt")]

use wildcat::attention::{exact_attention, wtd_attention, ClipRange};
use wildcat::linalg::Matrix;
use wildcat::model::{ModelBackend, ModelConfig, Transformer, WeightFile};
use wildcat::rng::Rng;
use wildcat::runtime::{LiteralArg, PjrtBackend, PjrtRuntime};

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        None
    }
}

#[test]
fn wtd_attn_artifact_matches_native() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = PjrtRuntime::open(&dir).unwrap();
    let name = "wtd_attn_256x96x64";
    if rt.manifest.artifact(name).is_none() {
        eprintln!("SKIP: {name} not exported");
        return;
    }
    let beta = rt.manifest.model.beta as f32;
    let mut rng = Rng::seed_from(3);
    let q = Matrix::randn(&mut rng, 256, 64);
    let ks = Matrix::randn(&mut rng, 96, 64);
    let vs = Matrix::randn(&mut rng, 96, 64);
    let w: Vec<f32> = (0..96).map(|_| rng.uniform_in(0.1, 2.0) as f32).collect();
    let (vmin, vmax) = vs.col_min_max();
    let outs = rt
        .execute_f32(
            name,
            &[
                LiteralArg::MatrixRef(&q),
                LiteralArg::MatrixRef(&ks),
                LiteralArg::MatrixRef(&vs),
                LiteralArg::F32(&w, vec![96]),
                LiteralArg::F32(&vmin, vec![64]),
                LiteralArg::F32(&vmax, vec![64]),
            ],
        )
        .unwrap();
    let got = Matrix::from_vec(outs[0].clone(), 256, 64);
    let w64: Vec<f64> = w.iter().map(|&x| x as f64).collect();
    let clip = ClipRange { lo: vmin, hi: vmax };
    let want = wtd_attention(&q, &ks, &vs, &w64, &clip, beta);
    let err = wildcat::linalg::norms::max_abs_diff(&got, &want);
    assert!(err < 1e-3, "PJRT vs native WTDATTN err={err}");
}

#[test]
fn exact_attn_artifact_matches_native() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = PjrtRuntime::open(&dir).unwrap();
    let name = "exact_attn_256x256x64";
    if rt.manifest.artifact(name).is_none() {
        eprintln!("SKIP: {name} not exported");
        return;
    }
    let beta = rt.manifest.model.beta as f32;
    let mut rng = Rng::seed_from(4);
    let q = Matrix::randn(&mut rng, 256, 64);
    let k = Matrix::randn(&mut rng, 256, 64);
    let v = Matrix::randn(&mut rng, 256, 64);
    let outs = rt
        .execute_f32(
            name,
            &[
                LiteralArg::MatrixRef(&q),
                LiteralArg::MatrixRef(&k),
                LiteralArg::MatrixRef(&v),
            ],
        )
        .unwrap();
    let got = Matrix::from_vec(outs[0].clone(), 256, 64);
    let want = exact_attention(&q, &k, &v, beta);
    let err = wildcat::linalg::norms::max_abs_diff(&got, &want);
    assert!(err < 1e-3, "PJRT vs native exact attention err={err}");
}

#[test]
fn pjrt_backend_matches_native_model() {
    // The production contract: the PJRT path (AOT HLO with baked weights)
    // and the native path (weights.bin) produce the same logits.
    let Some(dir) = artifacts_dir() else { return };
    let mut pjrt = PjrtBackend::open(&dir).unwrap();
    let weights = WeightFile::load(dir.join("weights.bin")).unwrap();
    let cfg = pjrt.config();
    let mut native = Transformer::from_weights(&weights, cfg).unwrap();

    let mut rng = Rng::seed_from(5);
    let n = 40;
    let tokens: Vec<u32> = (0..n).map(|_| 6 + rng.below(58) as u32).collect();

    // prefill parity
    let a = ModelBackend::prefill(&mut pjrt, &tokens);
    let b = ModelBackend::prefill(&mut native, &tokens);
    let logit_err: f32 = a
        .logits
        .iter()
        .zip(&b.logits)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0, f32::max);
    assert!(logit_err < 2e-2, "prefill logits diverge: {logit_err}");
    for lh in 0..cfg.n_layers * cfg.n_heads {
        assert_eq!(a.k_cache[lh].rows(), n);
        let err = wildcat::linalg::norms::max_abs_diff(&a.k_cache[lh], &b.k_cache[lh]);
        assert!(err < 1e-2, "k cache diverges at lh={lh}: {err}");
    }

    // decode parity over the (uncompressed) cache
    let caches: Vec<(Matrix, Matrix, Vec<f64>)> = b
        .k_cache
        .iter()
        .zip(&b.v_cache)
        .map(|(k, v)| (k.clone(), v.clone(), vec![1.0f64; k.rows()]))
        .collect();
    let refs: Vec<(&Matrix, &Matrix, &[f64])> =
        caches.iter().map(|(k, v, w)| (k, v, w.as_slice())).collect();
    let (la, ka, _va) = ModelBackend::decode(&mut pjrt, 7, n, &refs);
    let (lb, kb, _vb) = ModelBackend::decode(&mut native, 7, n, &refs);
    let derr: f32 = la.iter().zip(&lb).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max);
    assert!(derr < 2e-2, "decode logits diverge: {derr}");
    for (x, y) in ka[0].iter().zip(&kb[0]) {
        assert!((x - y).abs() < 1e-2);
    }
}

#[test]
fn pjrt_decode_capacity_selection() {
    let Some(dir) = artifacts_dir() else { return };
    let mut pjrt = PjrtBackend::open(&dir).unwrap();
    let cfg = pjrt.config();
    // small cache must route to the small decode artifact without error
    let mut rng = Rng::seed_from(6);
    let tokens: Vec<u32> = (0..10).map(|_| 6 + rng.below(58) as u32).collect();
    let out = ModelBackend::prefill(&mut pjrt, &tokens);
    let caches: Vec<(Matrix, Matrix, Vec<f64>)> = out
        .k_cache
        .iter()
        .zip(&out.v_cache)
        .map(|(k, v)| (k.clone(), v.clone(), vec![1.0f64; k.rows()]))
        .collect();
    let refs: Vec<(&Matrix, &Matrix, &[f64])> =
        caches.iter().map(|(k, v, w)| (k, v, w.as_slice())).collect();
    let (logits, nk, nv) = ModelBackend::decode(&mut pjrt, 3, 10, &refs);
    assert_eq!(logits.len(), cfg.vocab);
    assert_eq!(nk.len(), cfg.n_layers * cfg.n_heads);
    assert_eq!(nv[0].len(), cfg.d_head());
    assert!(logits.iter().all(|x| x.is_finite()));
}
