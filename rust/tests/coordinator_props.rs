//! Property tests on coordinator invariants (DESIGN.md §6):
//! * every admitted request is answered exactly once, none lost
//! * batch admission never exceeds configured maxima
//! * per-sequence caches never exceed budget + slack + 1
//! * rejected requests surface as rejections, not drops
//!
//! Extended to the cluster tier: every request submitted to the
//! [`Router`] is answered or rejected exactly once across replicas, for
//! random replica counts and routing policies.

use std::sync::Arc;
use std::time::Duration;
use wildcat::cluster::{ReplicaPool, Router, RouterConfig, RoutingPolicy};
use wildcat::coordinator::{
    AdmissionQueue, Batcher, BatcherConfig, Request, Scheduler, SchedulerConfig, Server,
    ServerConfig, ServingMetrics,
};
use wildcat::kvcache::{StreamingLlm, UniformKv};
use wildcat::model::{ModelConfig, Transformer};
use wildcat::rng::Rng;
use wildcat::util::prop::Cases;

fn tiny_model(seed: u64) -> Transformer {
    let cfg = ModelConfig { vocab: 16, d_model: 16, n_layers: 2, n_heads: 2, d_ff: 32, max_len: 512 };
    Transformer::random(cfg, &mut Rng::seed_from(seed))
}

#[test]
fn prop_no_request_lost_or_duplicated() {
    Cases::new(6).run(|rng| {
        let n_req = 3 + rng.below(8);
        let mut sched = Scheduler::new(
            tiny_model(7),
            SchedulerConfig { cache_budget: 64, slack: 8, ..Default::default() },
            Arc::new(StreamingLlm),
            Arc::new(ServingMetrics::new()),
            rng.next_u64(),
        );
        let batcher = Batcher::new(BatcherConfig {
            max_active: 1 + rng.below(6),
            max_admit_per_step: 1 + rng.below(3),
            max_wait: Duration::from_millis(1),
            soft_active: 1,
        });
        let reqs: Vec<Request> = (0..n_req)
            .map(|i| {
                let len = 4 + rng.below(60);
                Request::new(
                    i as u64,
                    (0..len).map(|j| (j % 16) as u32).collect(),
                    1 + rng.below(5),
                )
            })
            .collect();
        let want: Vec<(u64, usize)> = reqs.iter().map(|r| (r.id, r.max_new)).collect();
        let responses = sched.run_to_completion(reqs, &batcher);
        assert_eq!(responses.len(), n_req, "response count");
        let mut got: Vec<(u64, usize)> =
            responses.iter().map(|r| (r.id, r.tokens.len())).collect();
        got.sort_unstable();
        let mut want = want;
        want.sort_unstable();
        assert_eq!(got, want, "ids/token counts mismatch");
    });
}

#[test]
fn prop_cache_budget_never_exceeded() {
    Cases::new(4).run(|rng| {
        let budget = 48 + rng.below(32);
        let slack = 8;
        let mut sched = Scheduler::new(
            tiny_model(9),
            SchedulerConfig { cache_budget: budget, slack, ..Default::default() },
            Arc::new(StreamingLlm),
            Arc::new(ServingMetrics::new()),
            rng.next_u64(),
        );
        let batcher = Batcher::new(BatcherConfig::default());
        let reqs: Vec<Request> = (0..3)
            .map(|i| {
                Request::new(
                    i as u64,
                    (0..150).map(|j| (j % 16) as u32).collect(),
                    20 + rng.below(20),
                )
            })
            .collect();
        for r in sched.run_to_completion(reqs, &batcher) {
            assert!(
                r.cache_entries <= budget + slack + 1,
                "cache {} > budget {budget} + slack {slack} + 1",
                r.cache_entries
            );
        }
    });
}

#[test]
fn prop_admission_queue_conservation() {
    // Under concurrent producers and a consumer, every submitted request
    // is either rejected (observed by the producer) or drained exactly
    // once — nothing disappears.
    Cases::new(4).run(|rng| {
        let cap = 1 + rng.below(16);
        let q = Arc::new(AdmissionQueue::new(cap, 1000));
        let n_producers = 2 + rng.below(3);
        let per_producer = 30;
        let accepted = Arc::new(std::sync::Mutex::new(Vec::<u64>::new()));
        std::thread::scope(|s| {
            for p in 0..n_producers {
                let q = q.clone();
                let accepted = accepted.clone();
                s.spawn(move || {
                    for i in 0..per_producer {
                        let id = (p * 1000 + i) as u64;
                        if q.submit(Request::new(id, vec![1], 1)).is_ok() {
                            accepted.lock().unwrap().push(id);
                        }
                        std::thread::yield_now();
                    }
                });
            }
            let q2 = q.clone();
            let drained = s.spawn(move || {
                let mut got = Vec::new();
                loop {
                    match q2.pop_batch(4, Duration::from_millis(5)) {
                        None => break,
                        Some(batch) => {
                            if batch.is_empty() && got.len() >= 1 {
                                // idle; keep polling until closed
                            }
                            got.extend(batch.iter().map(|r| r.id));
                        }
                    }
                    if got.len() >= n_producers * per_producer {
                        break;
                    }
                    // producers may still be running
                    std::thread::yield_now();
                }
                got
            });
            // close after producers finish: drain the rest
            // (scope join order: spawn a closer thread that waits)
            let q3 = q.clone();
            s.spawn(move || {
                std::thread::sleep(Duration::from_millis(200));
                q3.close();
            });
            let mut got = drained.join().unwrap();
            // drain any remainder post-close
            while let Some(batch) = q.pop_batch(64, Duration::from_millis(5)) {
                got.extend(batch.iter().map(|r| r.id));
            }
            let mut acc = accepted.lock().unwrap().clone();
            acc.sort_unstable();
            got.sort_unstable();
            got.dedup();
            assert_eq!(got, acc, "drained set != accepted set");
        });
    });
}

#[test]
fn prop_cluster_router_answers_or_rejects_exactly_once() {
    // For random replica counts, routing policies, and (small) queue
    // capacities, every request submitted to the router is either
    // answered exactly once by some replica or surfaced as a rejection —
    // and the router's accounting agrees with the per-replica metrics.
    Cases::new(5).run(|rng| {
        let n_replicas = 1 + rng.below(4);
        let policy = RoutingPolicy::ALL[rng.below(RoutingPolicy::ALL.len())];
        let cfg = ServerConfig {
            queue_capacity: 2 + rng.below(8),
            max_prompt: 128,
            scheduler: SchedulerConfig { cache_budget: 96, slack: 8, ..Default::default() },
            ..Default::default()
        };
        let pool = Arc::new(ReplicaPool::spawn(n_replicas, cfg, Arc::new(StreamingLlm), |i| {
            tiny_model(30 + i as u64)
        }));
        let router = Router::new(
            pool.clone(),
            RouterConfig { policy, cooldown: Duration::from_millis(5), ..Default::default() },
        );
        let n_req = 10 + rng.below(30);
        let mut accepted = Vec::new();
        let mut rejected = 0usize;
        for k in 0..n_req {
            let len = 4 + rng.below(40);
            let prompt: Vec<u32> = (0..len).map(|j| (j % 16) as u32).collect();
            let max_new = 1 + rng.below(4);
            match router.submit(prompt, max_new, Some((k % 5) as u64)) {
                Ok(r) => accepted.push((r, max_new)),
                Err(_) => rejected += 1,
            }
        }
        let mut completed = 0usize;
        for (r, want) in accepted {
            let resp = r
                .wait(Duration::from_secs(120))
                .expect("accepted request must be answered");
            assert_eq!(resp.tokens.len(), want, "wrong response for request");
            completed += 1;
        }
        assert_eq!(
            completed + rejected,
            n_req,
            "every request must be answered or rejected exactly once"
        );
        let snap = router.snapshot();
        assert_eq!(snap.routed as usize, completed, "router routed-count drift");
        assert_eq!(snap.rejected as usize, rejected, "router reject-count drift");
        assert_eq!(snap.completed as usize, completed, "router completion drift");
        // replica-side conservation: completions across replicas sum to
        // the cluster total; nothing was double-served
        let replica_completed: u64 =
            (0..pool.len()).map(|i| pool.metrics(i).counters().completed).sum();
        assert_eq!(replica_completed as usize, completed, "replica completion drift");
        pool.shutdown();
    });
}

#[test]
fn server_end_to_end_under_load() {
    let cfg = ServerConfig {
        queue_capacity: 64,
        max_prompt: 512,
        scheduler: SchedulerConfig { cache_budget: 96, slack: 16, ..Default::default() },
        ..Default::default()
    };
    let handle = Server::spawn(cfg, Arc::new(UniformKv), || tiny_model(21));
    let mut rxs = Vec::new();
    let mut rng = Rng::seed_from(5);
    for _ in 0..20 {
        let len = 10 + rng.below(120);
        let prompt: Vec<u32> = (0..len).map(|_| rng.below(16) as u32).collect();
        let (id, rx) = handle.submit(prompt, 1 + rng.below(4)).unwrap();
        rxs.push((id, rx));
    }
    for (id, rx) in rxs {
        let resp = rx.recv_timeout(Duration::from_secs(120)).unwrap();
        assert_eq!(resp.id, id);
        assert!(!resp.tokens.is_empty());
    }
    let c = handle.metrics().counters();
    assert_eq!(c.completed, 20);
    assert_eq!(c.submitted, 20);
    handle.shutdown();
}
