//! Observability integration tests — the tracing/telemetry acceptance
//! contract:
//!
//! * concurrent recording into the ring loses nothing under capacity;
//! * a traced single-replica serve run exports a Chrome trace that
//!   parses with our own JSON parser, validates (paired B/E spans,
//!   per-lane monotone timestamps), carries one retired lane per
//!   completed request, and accounts each request's end-to-end latency
//!   within tolerance;
//! * a traced 2-replica cluster run lands `route` spans on the router
//!   process and lifecycle spans on both replica processes;
//! * the JSONL metrics series validates and its final sample's
//!   cumulative counters equal the end-of-run metrics snapshot.
//!
//! Tests touching the process-wide tracer serialize on a lock (this
//! binary's tests run concurrently on threads).

use std::sync::{Arc, Mutex, MutexGuard};
use std::time::Duration;
use wildcat::cluster::{ReplicaPool, Router, RouterConfig, RoutingPolicy};
use wildcat::coordinator::{Server, ServerConfig, ServerHandle};
use wildcat::kvcache::StreamingLlm;
use wildcat::model::{ModelConfig, Transformer};
use wildcat::obs::trace::{self, Event, SpanKind, Tracer};
use wildcat::obs::{chrome_trace, validate_chrome_trace, validate_series, MetricsSampler};
use wildcat::rng::Rng;
use wildcat::util::json::Json;

static GLOBAL_TRACER_LOCK: Mutex<()> = Mutex::new(());

fn lock_global() -> MutexGuard<'static, ()> {
    GLOBAL_TRACER_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn tiny_model(seed: u64) -> Transformer {
    let mcfg =
        ModelConfig { vocab: 16, d_model: 16, n_layers: 2, n_heads: 2, d_ff: 32, max_len: 256 };
    Transformer::random(mcfg, &mut Rng::seed_from(seed))
}

fn tiny_server() -> ServerHandle {
    Server::spawn(ServerConfig::default(), Arc::new(StreamingLlm), || tiny_model(9))
}

#[test]
fn concurrent_recording_loses_nothing_under_capacity() {
    let t = Arc::new(Tracer::new(100_000));
    t.set_enabled(true);
    let mut hs = Vec::new();
    for th in 0..8u64 {
        let t = Arc::clone(&t);
        hs.push(std::thread::spawn(move || {
            for i in 0..500u64 {
                t.record(Event {
                    ts_us: th * 1000 + i,
                    dur_us: 1,
                    kind: SpanKind::DecodeStep,
                    replica: th as u32,
                    req: th,
                    a: i,
                    b: 0,
                });
            }
        }));
    }
    for h in hs {
        h.join().unwrap();
    }
    let buf = t.drain();
    assert_eq!(buf.recorded, 4000);
    assert_eq!(buf.dropped, 0, "no events may drop below capacity");
    assert_eq!(buf.events.len(), 4000);
    // each thread's events kept their per-thread order
    for th in 0..8u64 {
        let seq: Vec<u64> = buf.events.iter().filter(|e| e.req == th).map(|e| e.a).collect();
        assert_eq!(seq.len(), 500, "thread {th} lost events");
        assert!(seq.windows(2).all(|w| w[0] < w[1]), "thread {th} order scrambled");
    }
}

#[test]
fn serve_trace_exports_retired_lanes_that_account_e2e() {
    let _g = lock_global();
    let tracer = trace::global();
    tracer.enable_with_capacity(65_536);

    let handle = tiny_server();
    let mut rxs = Vec::new();
    for i in 0..6u32 {
        let prompt: Vec<u32> = (0..8).map(|k| (k + i) % 12 + 2).collect();
        let (_, rx) = handle.submit(prompt, 3).unwrap();
        rxs.push(rx);
    }
    for rx in rxs {
        assert!(rx.recv_timeout(Duration::from_secs(60)).is_ok());
    }
    let completed = handle.metrics().counters().completed;
    handle.shutdown();

    tracer.set_enabled(false);
    let buf = tracer.drain();
    assert_eq!(completed, 6);
    assert!(buf.recorded > 0, "instrumentation recorded nothing");
    assert_eq!(buf.dropped, 0);
    // every lifecycle kind a single-replica serve run can produce
    for kind in [SpanKind::Queue, SpanKind::Prefill, SpanKind::DecodeStep, SpanKind::Retire] {
        assert!(
            buf.events.iter().any(|e| e.kind == kind),
            "no {} span recorded",
            kind.name()
        );
    }

    let doc = chrome_trace(&buf);
    // fixed point through our own parser (what `wildcat obs` re-reads)
    let text = doc.to_string_compact();
    assert_eq!(wildcat::util::json::parse(&text).unwrap(), doc);
    let s = validate_chrome_trace(&doc).expect("trace must validate");
    assert_eq!(s.retired, 6, "one retired lane per completed request");
    assert_eq!(s.dropped, 0);
    assert!(s.spans > 0 && s.lanes > 0);
}

#[test]
fn cluster_trace_covers_router_and_both_replicas() {
    let _g = lock_global();
    let tracer = trace::global();
    tracer.enable_with_capacity(65_536);

    let pool =
        Arc::new(ReplicaPool::spawn(2, ServerConfig::default(), Arc::new(StreamingLlm), |i| {
            tiny_model(21 + i as u64)
        }));
    let router = Router::new(
        pool.clone(),
        RouterConfig { policy: RoutingPolicy::RoundRobin, ..Default::default() },
    );
    let mut pending = Vec::new();
    for _ in 0..4 {
        pending.push(router.submit(vec![1, 2, 3], 2, None).unwrap());
    }
    for p in pending {
        assert!(p.wait(Duration::from_secs(60)).is_some());
    }
    pool.shutdown();

    tracer.set_enabled(false);
    let buf = tracer.drain();
    let routes: Vec<&Event> = buf.events.iter().filter(|e| e.kind == SpanKind::Route).collect();
    assert_eq!(routes.len(), 4, "one route span per submission");
    // round_robin over 2 replicas: both must take traffic, and the
    // accepting replica is echoed in the payload
    for r in &routes {
        assert_eq!(r.replica as u64, r.b, "route payload disagrees with lane replica");
    }
    assert!(routes.iter().any(|e| e.replica == 0) && routes.iter().any(|e| e.replica == 1));

    let doc = chrome_trace(&buf);
    let s = validate_chrome_trace(&doc).expect("cluster trace must validate");
    assert_eq!(s.retired, 4);
    let text = doc.to_string_compact();
    assert!(text.contains("\"router\""), "router process missing from export");
    assert!(text.contains("\"replica 0\"") && text.contains("\"replica 1\""));
}

#[test]
fn series_final_sample_matches_end_of_run_counters() {
    let dir = std::env::temp_dir().join(format!("wildcat_obs_it_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("series.jsonl");

    let handle = tiny_server();
    let client = handle.client();
    let run = wildcat::obs::run_meta("test-serve", 0, vec![("replicas", Json::Num(1.0))]);
    let sampler = MetricsSampler::start(&path, run, Duration::from_millis(20), move || {
        client.metrics().to_json()
    })
    .unwrap();

    let mut rxs = Vec::new();
    for _ in 0..5 {
        let (_, rx) = handle.submit(vec![2, 3, 4, 5], 2).unwrap();
        rxs.push(rx);
    }
    for rx in rxs {
        assert!(rx.recv_timeout(Duration::from_secs(60)).is_ok());
    }
    // all responses received: counters are final before the sampler stops
    let n = sampler.stop().unwrap();
    let end = handle.metrics().counters();
    handle.shutdown();

    let text = std::fs::read_to_string(&path).unwrap();
    let summary = validate_series(&text).expect("series must validate");
    assert_eq!(summary.samples as u64, n);
    let last_line = text.lines().filter(|l| !l.trim().is_empty()).last().unwrap();
    let last = wildcat::util::json::parse(last_line).unwrap();
    assert_eq!(last.get("completed").and_then(Json::as_f64), Some(end.completed as f64));
    assert_eq!(
        last.get("tokens_generated").and_then(Json::as_f64),
        Some(end.tokens_generated as f64)
    );
    assert_eq!(end.completed, 5);
    let _ = std::fs::remove_dir_all(&dir);
}
