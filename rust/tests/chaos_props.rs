//! Chaos acceptance tests — the PR-9 robustness contract:
//!
//! * under randomized deterministic fault schedules (worker crashes,
//!   decode stalls, transient admission failures) against random replica
//!   counts and routing policies, **every submitted request reaches
//!   exactly one terminal [`Outcome`]** — completed, rejected(reason) or
//!   deadline exceeded — and nothing hangs;
//! * crashed replicas are respawned by the pool supervisor and serve
//!   again once the fault plan is disarmed;
//! * a wall-clock chaos soak with forced crashes, stalls and injected
//!   rejects records restarts and failovers in the router's snapshot and
//!   metrics JSON while conserving outcomes;
//! * a fault-free run's deterministic counters and token streams are
//!   bit-identical whether the fault plane is absent (`faults: None`) or
//!   present but disarmed — the plane is zero-cost when off.

use std::sync::Arc;
use std::time::Duration;
use wildcat::cluster::{
    FaultConfig, FaultPlan, Outcome, ReplicaPool, Router, RouterConfig, RoutingPolicy,
};
use wildcat::coordinator::{SchedulerConfig, ServerConfig};
use wildcat::kvcache::StreamingLlm;
use wildcat::model::{ModelConfig, Transformer};
use wildcat::rng::Rng;
use wildcat::util::json::Json;
use wildcat::util::prop::Cases;

fn tiny_model(seed: u64) -> Transformer {
    let mcfg =
        ModelConfig { vocab: 16, d_model: 16, n_layers: 2, n_heads: 2, d_ff: 32, max_len: 256 };
    Transformer::random(mcfg, &mut Rng::seed_from(seed))
}

fn chaos_server_cfg(queue_capacity: usize, faults: Option<Arc<FaultPlan>>) -> ServerConfig {
    ServerConfig {
        queue_capacity,
        max_prompt: 128,
        scheduler: SchedulerConfig { cache_budget: 96, slack: 8, ..Default::default() },
        faults,
        ..Default::default()
    }
}

/// The core property: for random fault schedules, replica counts and
/// routing policies, every request submitted to the router reaches
/// exactly one terminal outcome (none lost, none double-counted), and
/// after the chaos phase ends the respawned replicas serve again.
#[test]
fn prop_every_request_reaches_exactly_one_terminal_outcome_under_chaos() {
    Cases::new(3).run(|rng| {
        let n_replicas = 1 + rng.below(3);
        let policy = RoutingPolicy::ALL[rng.below(RoutingPolicy::ALL.len())];
        let fcfg = FaultConfig {
            seed: rng.next_u64(),
            crash_every: (4 + rng.below(8)) as u64,
            stall_every: (5 + rng.below(6)) as u64,
            stall_ms: 1,
            reject_every: (3 + rng.below(5)) as u64,
        };
        let plan = FaultPlan::new(fcfg, n_replicas).expect("active plan");
        let cfg = chaos_server_cfg(4 + rng.below(8), Some(plan.clone()));
        let pool = Arc::new(ReplicaPool::spawn(n_replicas, cfg, Arc::new(StreamingLlm), |i| {
            tiny_model(60 + i as u64)
        }));
        let router = Router::new(
            pool.clone(),
            RouterConfig {
                policy,
                cooldown: Duration::from_millis(5),
                max_retries: 2,
                backoff_base: Duration::from_millis(1),
                backoff_cap: Duration::from_millis(5),
                seed: rng.next_u64(),
                ..Default::default()
            },
        );
        let n_req = 12 + rng.below(12);
        let (mut completed, mut rejected, mut deadline) = (0usize, 0usize, 0usize);
        for k in 0..n_req {
            let len = 4 + rng.below(24);
            let prompt: Vec<u32> = (0..len).map(|j| ((j + k) % 16) as u32).collect();
            let max_new = 1 + rng.below(3);
            let outcome = match router.submit(prompt, max_new, Some((k % 4) as u64)) {
                Ok(r) => router.await_outcome(r, Duration::from_secs(120)),
                Err(o) => o,
            };
            match outcome {
                Outcome::Completed(_) => completed += 1,
                Outcome::Rejected(_) => rejected += 1,
                Outcome::DeadlineExceeded => deadline += 1,
            }
        }
        assert_eq!(completed + rejected + deadline, n_req, "an outcome per request");
        let s = router.snapshot();
        assert_eq!(s.requests as usize, n_req, "submission count drift");
        assert_eq!(s.terminal(), s.requests, "terminal-outcome conservation: {s:?}");
        assert_eq!(s.completed as usize, completed, "completion drift");
        assert_eq!(s.rejected as usize, rejected, "rejection drift");
        assert_eq!(s.deadline_exceeded as usize, deadline, "deadline drift");

        // end the chaos phase: every replica must serve again afterwards
        plan.disarm();
        pool.supervise();
        for k in 0..(2 * n_replicas) {
            let r = router
                .submit(vec![1, 2, 3, (k % 16) as u32], 2, Some(k as u64))
                .expect("recovered cluster must accept requests");
            let o = router.await_outcome(r, Duration::from_secs(60));
            assert!(o.is_completed(), "recovered cluster must serve, got {}", o.name());
        }
        let s2 = router.snapshot();
        assert_eq!(s2.terminal(), s2.requests, "conservation after recovery: {s2:?}");
        pool.shutdown();
    });
}

/// A fixed-seed wall-clock soak: forced crashes, stalls and injected
/// rejects against a 2-replica round-robin cluster. Every request must
/// reach one terminal outcome while the router records the chaos —
/// restarts, failovers and breaker state all land in the snapshot, the
/// metrics JSON and the Prometheus exposition.
#[test]
fn chaos_soak_records_restarts_and_failovers_while_conserving_outcomes() {
    let plan = FaultPlan::new(
        FaultConfig { seed: 4242, crash_every: 6, stall_every: 9, stall_ms: 2, reject_every: 7 },
        2,
    )
    .expect("active plan");
    let pool = Arc::new(ReplicaPool::spawn(
        2,
        chaos_server_cfg(16, Some(plan.clone())),
        Arc::new(StreamingLlm),
        |i| tiny_model(70 + i as u64),
    ));
    let router = Router::new(
        pool.clone(),
        RouterConfig {
            policy: RoutingPolicy::RoundRobin,
            request_timeout: Duration::from_secs(5),
            max_retries: 3,
            backoff_base: Duration::from_millis(1),
            backoff_cap: Duration::from_millis(10),
            seed: 7,
            ..Default::default()
        },
    );
    let n_req = 48usize;
    let (mut completed, mut rejected, mut deadline) = (0usize, 0usize, 0usize);
    for k in 0..n_req {
        let prompt: Vec<u32> = (0..8).map(|j| ((j + k) % 16) as u32).collect();
        let outcome = match router.submit(prompt, 3, None) {
            Ok(r) => router.await_outcome(r, Duration::from_secs(60)),
            Err(o) => o,
        };
        match outcome {
            Outcome::Completed(_) => completed += 1,
            Outcome::Rejected(_) => rejected += 1,
            Outcome::DeadlineExceeded => deadline += 1,
        }
    }
    assert_eq!(completed + rejected + deadline, n_req, "outcome conservation");
    assert!(completed > 0, "chaos must not starve the cluster entirely");
    assert!(plan.crashes() >= 2, "soak must force >= 2 crashes, got {}", plan.crashes());
    let s = router.snapshot();
    assert_eq!(s.requests as usize, n_req);
    assert_eq!(s.terminal(), s.requests, "terminal-outcome conservation: {s:?}");
    assert!(s.restarts >= 1, "crashed replicas must be restarted: {s:?}");
    assert!(s.failovers >= 1, "in-flight requests on crashed replicas must fail over: {s:?}");

    let j = router.metrics_json();
    assert!(
        j.get("restarts").and_then(Json::as_f64).unwrap() >= 1.0,
        "metrics JSON must surface restarts"
    );
    let agg = j.get("aggregate").expect("aggregate block");
    assert_eq!(agg.get("requests").and_then(Json::as_f64), Some(n_req as f64));
    assert_eq!(
        agg.get("failovers").and_then(Json::as_f64),
        Some(s.failovers as f64),
        "aggregate failovers drift"
    );
    let reps = j.get("replicas").unwrap().as_arr().unwrap();
    let restarts_sum: f64 =
        reps.iter().map(|r| r.get("restarts").and_then(Json::as_f64).unwrap()).sum();
    assert_eq!(restarts_sum, s.restarts as f64, "per-replica restarts must sum to the total");
    for r in reps {
        assert!(r.get("breaker_state").and_then(Json::as_str).is_some(), "breaker state missing");
    }
    let prom = router.to_prometheus();
    assert!(prom.contains("wildcat_cluster_failovers_total"), "prom:\n{prom}");
    assert!(prom.contains("wildcat_cluster_restarts_total"), "prom:\n{prom}");
    assert!(prom.contains("wildcat_replica_restarts_total"), "prom:\n{prom}");

    // disarm and verify the respawned replicas keep serving
    plan.disarm();
    pool.supervise();
    for _ in 0..4 {
        let r = router.submit(vec![1, 2, 3, 4], 2, None).expect("recovered cluster accepts");
        assert!(router.await_outcome(r, Duration::from_secs(60)).is_completed());
    }
    let s2 = router.snapshot();
    assert_eq!(s2.terminal(), s2.requests, "conservation after recovery: {s2:?}");
    pool.shutdown();
}

/// Failover affinity re-pin: when an affinity session's home replica
/// crashes mid-request, the failed-over request lands on a survivor and
/// the session is re-pinned there — subsequent requests of the same
/// session follow the warm KV state to the survivor instead of bouncing
/// back to the freshly respawned (cold) home.
#[test]
fn failover_repins_affinity_session_to_surviving_replica() {
    // crash each replica's worker on its very first engine step; only
    // the session's home ever receives work while the plan is armed
    let plan = FaultPlan::new(FaultConfig { seed: 3, crash_every: 1, ..Default::default() }, 2)
        .expect("active plan");
    let pool = Arc::new(ReplicaPool::spawn(
        2,
        chaos_server_cfg(16, Some(plan.clone())),
        Arc::new(StreamingLlm),
        |i| tiny_model(80 + i as u64),
    ));
    let router = Router::new(
        pool.clone(),
        RouterConfig {
            policy: RoutingPolicy::Affinity,
            max_retries: 2,
            backoff_base: Duration::from_millis(1),
            backoff_cap: Duration::from_millis(5),
            seed: 11,
            ..Default::default()
        },
    );
    let session = 42u64;
    assert_eq!(router.pinned_replica(session), None, "no pin before any failover");
    let r = router.submit(vec![1, 2, 3, 4], 2, Some(session)).expect("healthy cluster accepts");
    let home = r.replica;
    // the injected crash kills the home worker at its first engine step
    let mut died = false;
    for _ in 0..1000 {
        if pool.worker_died(home) {
            died = true;
            break;
        }
        std::thread::sleep(Duration::from_millis(2));
    }
    assert!(died, "injected crash never killed the home replica");
    // end the chaos phase before driving the failover so the survivor
    // (which has not stepped yet) does not crash on the re-routed work
    plan.disarm();
    let outcome = router.await_outcome(r, Duration::from_secs(60));
    assert!(outcome.is_completed(), "failed-over request must complete, got {}", outcome.name());
    let pinned = router.pinned_replica(session).expect("failover must record a pin");
    assert_ne!(pinned, home, "the pin must point at the survivor, not the crashed home");
    // later requests of the session follow the pin to the survivor
    for _ in 0..3 {
        let r2 = router.submit(vec![5, 6, 7], 1, Some(session)).expect("survivor accepts");
        assert_eq!(r2.replica, pinned, "session must stay on its re-pinned replica");
        assert!(router.await_outcome(r2, Duration::from_secs(60)).is_completed());
    }
    // a different session still follows its hash (no global re-pin)
    assert_eq!(router.pinned_replica(session + 1), None);
    let s = router.snapshot();
    assert!(s.failovers >= 1, "the crash must surface as a failover: {s:?}");
    assert_eq!(s.terminal(), s.requests, "outcome conservation: {s:?}");
    pool.shutdown();
}

/// Run a fixed single-replica workload and return its token streams plus
/// the deterministic router counters.
fn run_fixed_workload(faults: Option<Arc<FaultPlan>>) -> (Vec<Vec<u32>>, Vec<u64>) {
    let pool = Arc::new(ReplicaPool::spawn(
        1,
        chaos_server_cfg(32, faults),
        Arc::new(StreamingLlm),
        |_| tiny_model(33),
    ));
    let router = Router::new(
        pool.clone(),
        RouterConfig { policy: RoutingPolicy::RoundRobin, seed: 5, ..Default::default() },
    );
    let mut outputs = Vec::new();
    for k in 0..10usize {
        let prompt: Vec<u32> = (0..6).map(|j| ((j * 3 + k) % 16) as u32).collect();
        let r = router.submit(prompt, 2, None).expect("fault-free run must accept");
        match router.await_outcome(r, Duration::from_secs(60)) {
            Outcome::Completed(resp) => outputs.push(resp.tokens),
            other => panic!("fault-free request must complete, got {}", other.name()),
        }
    }
    let s = router.snapshot();
    let counters = vec![
        s.requests,
        s.routed,
        s.completed,
        s.rejected,
        s.rerouted,
        s.deadline_exceeded,
        s.failovers,
        s.retries,
        s.restarts,
        s.tokens_generated,
    ];
    pool.shutdown();
    (outputs, counters)
}

/// The zero-cost-when-off guarantee: a fault-free run produces
/// bit-identical token streams and deterministic counters whether the
/// fault plane is absent entirely or present but disarmed.
#[test]
fn fault_free_run_is_bit_identical_with_and_without_the_fault_plane() {
    let (out_none, counters_none) = run_fixed_workload(None);
    let plan = FaultPlan::new(
        FaultConfig { seed: 1, crash_every: 5, stall_every: 3, stall_ms: 1, reject_every: 2 },
        1,
    )
    .expect("active plan");
    plan.disarm(); // the plane sits in the hot path but injects nothing
    let (out_plan, counters_plan) = run_fixed_workload(Some(plan.clone()));
    assert_eq!(out_none, out_plan, "token streams must be bit-identical");
    assert_eq!(counters_none, counters_plan, "deterministic counters must be bit-identical");
    assert_eq!(
        plan.crashes() + plan.stalls() + plan.injected_rejects(),
        0,
        "a disarmed plan must count nothing"
    );
}
