//! Property test: every compression policy behind `compressor_by_name`
//! honours its budget — `entry.len() <= budget` for random shapes and
//! budgets, including `budget >= n` (verbatim passthrough) and the
//! `split_protected` edge cases `budget = 0 / 1 / 2` where the
//! protected-ends protocol cannot run and the shared tiny-budget
//! fallback must kick in.
//!
//! The one nuance is PyramidKV, whose *per-layer* budget pyramids around
//! the requested mean (early layers keep more, late layers less); its
//! contract is `entry.len() <= layer_budget(budget, layer, n_layers)`,
//! which is what the pool's capacity accounting sees per layer.

use wildcat::kvcache::{
    compressor_by_name, CompressionCtx, PyramidKv, COMPRESSOR_NAMES,
};
use wildcat::linalg::Matrix;
use wildcat::rng::Rng;
use wildcat::util::prop::Cases;

fn budget_for_case(rng: &mut Rng, n: usize) -> usize {
    // weight the interesting regions: tiny budgets, mid-range, >= n
    match rng.below(6) {
        0 => 0,
        1 => 1,
        2 => 2,
        3 => 1 + rng.below(n.max(1)),
        4 => n,
        _ => n + 1 + rng.below(64),
    }
}

#[test]
fn every_compressor_honours_its_budget() {
    Cases::new(48).run(|rng| {
        let n = 2 + rng.below(300);
        let d = [2, 4, 8][rng.below(3)];
        let dv = [2, 4, 8][rng.below(3)];
        let keys = Matrix::randn(rng, n, d);
        let values = Matrix::randn(rng, n, dv);
        let n_layers = 1 + rng.below(4);
        let layer = rng.below(n_layers);
        let budget = budget_for_case(rng, n);
        let with_obs = rng.below(2) == 1;
        let obs = Matrix::randn(rng, 4, d);
        for name in COMPRESSOR_NAMES {
            let comp = compressor_by_name(name).unwrap();
            let ctx = CompressionCtx {
                keys: &keys,
                values: &values,
                budget,
                beta: 0.35,
                layer,
                n_layers,
                obs_queries: if with_obs { Some(&obs) } else { None },
            };
            let entry = comp.compress(&ctx, rng);
            // PyramidKV's effective budget is its per-layer pyramid value
            let allowed = if name == "pyramidkv" {
                PyramidKv::default().layer_budget(budget, layer, n_layers)
            } else {
                budget
            };
            assert!(
                entry.len() <= allowed,
                "{name}: n={n} d={d} budget={budget} (allowed {allowed}) -> {} entries",
                entry.len()
            );
            assert_eq!(
                entry.weights.len(),
                entry.len(),
                "{name}: weights/rows mismatch at n={n} budget={budget}"
            );
            assert_eq!(entry.source_len, n, "{name}: wrong source_len");
            assert_eq!(entry.keys.cols(), d, "{name}: key width changed");
            assert_eq!(entry.values.cols(), dv, "{name}: value width changed");
            if allowed >= n {
                assert_eq!(
                    entry.len(),
                    n,
                    "{name}: budget >= n must keep the context verbatim"
                );
            }
        }
    });
}

/// The tiny-budget fallback specifically: budgets 0/1/2 on contexts far
/// larger than the protected window still come back exactly sized.
#[test]
fn tiny_budgets_shrink_instead_of_passing_through() {
    let mut rng = Rng::seed_from(7);
    let keys = Matrix::randn(&mut rng, 200, 4);
    let values = Matrix::randn(&mut rng, 200, 4);
    for budget in [0usize, 1, 2] {
        for name in COMPRESSOR_NAMES {
            let comp = compressor_by_name(name).unwrap();
            let ctx = CompressionCtx {
                keys: &keys,
                values: &values,
                budget,
                beta: 0.35,
                layer: 0,
                n_layers: 2,
                obs_queries: None,
            };
            let entry = comp.compress(&ctx, &mut rng);
            let allowed = if name == "pyramidkv" {
                PyramidKv::default().layer_budget(budget, 0, 2)
            } else {
                budget
            };
            assert!(
                entry.len() <= allowed,
                "{name}: budget {budget} (allowed {allowed}) -> {} entries",
                entry.len()
            );
        }
    }
}
