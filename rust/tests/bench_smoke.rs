//! Integration tests for the self-measuring contract introduced with the
//! `wildcat bench` runner:
//!
//! * the paper's qualitative error-decay claim — WildCat's attention error
//!   shrinks as the coreset rank grows on a fixed-seed Gaussian workload
//!   (the empirical counterpart of the super-polynomial decay guarantee);
//! * `wildcat bench --smoke` output round-trips through the BENCH_*.json
//!   schema: written files parse, validate, and re-serialise to the same
//!   document.

use wildcat::attention::{exact_attention, wildcat_attention, WildcatParams};
use wildcat::bench::report::validate_str;
use wildcat::bench::runners::{run_all, RunCfg};
use wildcat::linalg::norms::max_abs_diff;
use wildcat::linalg::Matrix;
use wildcat::rng::Rng;
use wildcat::util::cli::Args;
use wildcat::util::json::parse;

/// Error monotonically shrinks as rank grows (averaged over RPNYS seeds;
/// "monotone" allows the small Monte-Carlo wiggle the paper's Fig. M.1
/// also shows — every step must stay within 1.2x of the previous level,
/// and the overall trend must be strictly decreasing).
#[test]
fn wildcat_error_monotone_in_rank() {
    let mut data_rng = Rng::seed_from(71);
    let n = 256;
    let q = Matrix::randn(&mut data_rng, 64, 8);
    let k = Matrix::randn(&mut data_rng, n, 8);
    let v = Matrix::randn(&mut data_rng, n, 4);
    let beta = 0.35f32;
    let exact = exact_attention(&q, &k, &v, beta);

    let ranks = [4usize, 16, 64, 192];
    let mut errs = Vec::new();
    for &rank in &ranks {
        let mut tot = 0.0;
        for seed in 0..4u64 {
            let mut rng = Rng::seed_from(1000 + seed);
            let params = WildcatParams { rank, bins: 1, beta: Some(beta as f64) };
            let o = wildcat_attention(&q, &k, &v, &params, &mut rng);
            tot += max_abs_diff(&o, &exact);
        }
        errs.push(tot / 4.0);
    }
    for w in errs.windows(2) {
        assert!(
            w[1] <= w[0] * 1.2 + 1e-9,
            "error increased along the rank sweep: {errs:?}"
        );
    }
    assert!(
        errs[ranks.len() - 1] < errs[0] * 0.5,
        "error did not shrink substantially from r={} to r={}: {errs:?}",
        ranks[0],
        ranks[ranks.len() - 1]
    );
    // near-full rank is near-exact
    let mut rng = Rng::seed_from(9);
    let params = WildcatParams { rank: n, bins: 1, beta: Some(beta as f64) };
    let o = wildcat_attention(&q, &k, &v, &params, &mut rng);
    assert!(max_abs_diff(&o, &exact) < 2e-4);
}

/// `wildcat bench --smoke` writes schema-valid JSON that survives a full
/// parse → validate → serialise → parse round trip. Runs a two-bench
/// subset at tiny shapes so the test stays seconds-scale.
#[test]
fn bench_smoke_reports_roundtrip_schema() {
    let out = std::env::temp_dir().join(format!("wildcat_bench_smoke_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&out);
    std::fs::create_dir_all(&out).unwrap();

    let args = Args::parse([
        "--smoke",
        "--min-exp",
        "8",
        "--max-exp",
        "9",
        "--err-seeds",
        "1",
        "--trials",
        "1",
    ]);
    let cfg = RunCfg::from_args(&args);
    let written = run_all(&cfg, &out, Some("fig3,table5")).unwrap();
    assert_eq!(written.len(), 2, "expected one report per requested bench");

    let mut saw_coreset = false;
    for path in &written {
        let text = std::fs::read_to_string(path).unwrap();
        let j = validate_str(&text).unwrap_or_else(|e| panic!("{}: {e}", path.display()));
        // round trip: serialise + reparse is a fixed point
        let again = parse(&j.to_string_compact()).unwrap();
        assert_eq!(again, j, "{}: serialisation not a fixed point", path.display());
        assert_eq!(j.get("mode").unwrap().as_str(), Some("smoke"));
        let records = j.get("records").unwrap().as_arr().unwrap();
        assert!(!records.is_empty());
        for r in records {
            assert!(r.get("name").unwrap().as_str().is_some());
            let ns = r.get("median_ns").unwrap().as_f64().unwrap();
            assert!(ns >= 0.0 && ns.is_finite());
            if r.get("coreset_size").map(|c| c.as_f64().is_some()).unwrap_or(false) {
                saw_coreset = true;
            }
        }
    }
    assert!(saw_coreset, "no record carried a coreset size");

    // unknown bench ids are rejected up front
    assert!(run_all(&cfg, &out, Some("nope")).is_err());
    let _ = std::fs::remove_dir_all(&out);
}
