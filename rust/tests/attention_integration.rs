//! Cross-module integration tests over the attention stack: the Thm. 1 /
//! Lem. 2 error chain measured end to end, invariances from Sec. 2.4, and
//! compressor-fidelity orderings that Tab. 4 depends on.

use wildcat::attention::{
    compress_kv, exact_attention, wildcat_attention, wtd_attention, ClipRange, CompressOpts,
    WildcatParams,
};
use wildcat::kernels::{kernel_cross, recenter_keys, temperature};
use wildcat::linalg::norms::{max_abs, max_abs_diff, norm_2inf};
use wildcat::linalg::{op_norm_sym_f64, Matrix};
use wildcat::rng::Rng;
use wildcat::rpnys::{residual_op_norm, rpnys};
use wildcat::util::prop::Cases;

/// Lem. 2 chain: ‖A − Â‖²_{2,∞} ≤ exp(β R_Q²) ‖h_res(K,K)‖_op, with the
/// Nyström Â built from RPNYS output. Measured, not just asserted in the
/// abstract: we verify the bound holds numerically.
#[test]
fn lemma2_nystrom_bound_holds() {
    Cases::new(6).run(|rng| {
        let n = 24 + rng.below(24);
        let m = 8 + rng.below(16);
        let d = 2 + rng.below(4);
        let beta = 0.3f64;
        let q = Matrix::randn(rng, m, d).scale(0.8);
        let k = Matrix::randn(rng, n, d).scale(0.8);
        let approx = rpnys(&k, beta, 8.min(n), rng);
        // Â = h(Q, K_S) W ; A = h(Q, K)
        let ks = k.select_rows(&approx.indices);
        let r = approx.rank();
        let h_qs = kernel_cross(&q, &ks, beta); // m×r
        let a_true = kernel_cross(&q, &k, beta); // m×n
        let mut a_hat = vec![0.0f64; m * n];
        for i in 0..m {
            for l in 0..n {
                let mut acc = 0.0;
                for j in 0..r {
                    acc += h_qs[i * r + j] * approx.weights[j * n + l];
                }
                a_hat[i * n + l] = acc;
            }
        }
        // ‖A − Â‖_{2,∞}
        let mut row_err_max: f64 = 0.0;
        for i in 0..m {
            let s: f64 = (0..n)
                .map(|l| (a_true[i * n + l] - a_hat[i * n + l]).powi(2))
                .sum();
            row_err_max = row_err_max.max(s);
        }
        let res_norm = residual_op_norm(&k, &approx, beta);
        let r_q = q.max_row_norm();
        let bound = (beta * r_q * r_q).exp() * res_norm;
        assert!(
            row_err_max <= bound * 1.05 + 1e-9,
            "Lem.2 violated: {row_err_max} > {bound}"
        );
    });
}

/// Thm. 1 direction: expected residual decays roughly like the best
/// low-rank approximation as r grows (checked as strict improvement over
/// a wide rank range plus near-zero at full rank).
#[test]
fn thm1_residual_decay() {
    let mut data_rng = Rng::seed_from(1);
    let n = 64;
    let k = Matrix::randn(&mut data_rng, n, 3);
    let h = kernel_cross(&k, &k, 0.4);
    let h_norm = op_norm_sym_f64(&h, n, 100);
    let avg_err = |r: usize| -> f64 {
        let mut tot = 0.0;
        for s in 0..4 {
            let mut rng = Rng::seed_from(50 + s);
            let a = rpnys(&k, 0.4, r, &mut rng);
            tot += residual_op_norm(&k, &a, 0.4);
        }
        tot / 4.0
    };
    let e4 = avg_err(4);
    let e16 = avg_err(16);
    let e64 = avg_err(64);
    assert!(e16 < e4, "e4={e4} e16={e16}");
    assert!(e64 < 1e-5 * h_norm, "full rank not exact: {e64}");
}

/// Sec. 2.4 invariances on the full WILDCAT pipeline: recentring the keys
/// must not change the output beyond Monte-Carlo noise (the pipeline
/// recentres internally, so we compare two *differently shifted* inputs
/// under the same seed).
#[test]
fn wildcat_shift_invariance() {
    let mut rng = Rng::seed_from(2);
    let q = Matrix::randn(&mut rng, 40, 6);
    let k = Matrix::randn(&mut rng, 120, 6);
    let v = Matrix::randn(&mut rng, 120, 4);
    let shift: Vec<f32> = (0..6).map(|i| 1.5 * ((i as f32) - 2.0)).collect();
    let k_shift = k.sub_row_vector(&shift);
    let params = WildcatParams { rank: 24, bins: 2, beta: Some(0.3) };
    let a = wildcat_attention(&q, &k, &v, &params, &mut Rng::seed_from(77));
    let b = wildcat_attention(&q, &k_shift, &v, &params, &mut Rng::seed_from(77));
    // recentring maps both to the SAME internal keys, so with the same
    // seed the pipelines are identical up to float noise
    let err = max_abs_diff(&a, &b);
    assert!(err < 2e-3, "shift changed the output: {err}");
}

/// Lem. 1's clipping: the WildCat output entries always lie in the
/// per-column value range even at tiny rank (where raw ratios explode).
#[test]
fn clipping_bounds_any_rank() {
    Cases::new(8).run(|rng| {
        let n = 32 + rng.below(64);
        let q = Matrix::randn(rng, 16, 8).scale(3.0);
        let k = Matrix::randn(rng, n, 8).scale(3.0);
        let v = Matrix::randn(rng, n, 3);
        let params = WildcatParams { rank: 1 + rng.below(4), bins: 1, beta: Some(1.0) };
        let o = wildcat_attention(&q, &k, &v, &params, rng);
        let (mn, mx) = v.col_min_max();
        for i in 0..o.rows() {
            for j in 0..o.cols() {
                assert!(o.get(i, j) >= mn[j] - 1e-6 && o.get(i, j) <= mx[j] + 1e-6);
            }
        }
    });
}

/// The temperature rule (Eq. 4) helps: compare WildCat error with the
/// chosen τ against a deliberately mis-scaled kernel (τ = 1, no
/// rescaling) at the same rank on anisotropic keys.
#[test]
fn temperature_improves_accuracy() {
    let mut data_rng = Rng::seed_from(3);
    let n = 256;
    let d = 8;
    let q = Matrix::randn(&mut data_rng, 64, d).scale(1.2);
    let mut k = Matrix::randn(&mut data_rng, n, d).scale(1.2);
    // anisotropy: one heavy direction, making raw H poorly conditioned
    for i in 0..n {
        let boost = 3.0 * (i as f32 / n as f32 - 0.5);
        k.row_mut(i)[0] += boost;
    }
    let v = Matrix::randn(&mut data_rng, n, 4);
    let beta = 0.5f64;
    let exact = exact_attention(&q, &k, &v, beta as f32);
    let clip = ClipRange::from_values(&v);
    let rank = 24;

    let err_with = |use_temp: bool| -> f64 {
        let mut tot = 0.0;
        for s in 0..5 {
            let mut rng = Rng::seed_from(100 + s);
            let rc = recenter_keys(&k);
            let r_k = rc.keys.max_row_norm();
            let tau = if use_temp {
                temperature(beta, q.max_row_norm(), r_k, n)
            } else {
                1.0
            };
            let approx = rpnys(&rc.keys, beta / (tau * tau), rank, &mut rng);
            let mut ks = rc.keys.select_rows(&approx.indices);
            ks.add_row_vector_mut(&rc.mean);
            let vs = approx.compress_values(&v);
            let w = approx.weight_row_sums();
            let o = wtd_attention(&q, &ks, &vs, &w, &clip, beta as f32);
            tot += max_abs_diff(&o, &exact);
        }
        tot / 5.0
    };
    let with_t = err_with(true);
    let without_t = err_with(false);
    assert!(
        with_t <= without_t * 1.25,
        "temperature hurt badly: with={with_t} without={without_t}"
    );
}

/// End-to-end serving fidelity ordering at matched budget: CompressKV's
/// weighted coreset tracks exact attention better than StreamingLLM's
/// recency window on uniformly-spread key mass.
#[test]
fn compression_fidelity_ordering() {
    use wildcat::kvcache::{CompressKvPolicy, CompressionCtx, KvCompressor, StreamingLlm};
    let mut data_rng = Rng::seed_from(4);
    let n = 512;
    let k = Matrix::randn(&mut data_rng, n, 8);
    let v = Matrix::randn(&mut data_rng, n, 4);
    let q = Matrix::randn(&mut data_rng, 32, 8);
    let beta = 0.35f32;
    let exact = exact_attention(&q, &k, &v, beta);
    let clip = ClipRange::from_values(&v);
    let fidelity = |comp: &dyn KvCompressor| -> f64 {
        let mut tot = 0.0;
        for s in 0..4 {
            let mut rng = Rng::seed_from(10 + s);
            let ctx = CompressionCtx {
                keys: &k,
                values: &v,
                budget: 128,
                beta: beta as f64,
                layer: 0,
                n_layers: 1,
                obs_queries: None,
            };
            let e = comp.compress(&ctx, &mut rng);
            tot += max_abs_diff(&wtd_attention(&q, &e.keys, &e.values, &e.weights, &clip, beta), &exact);
        }
        tot / 4.0
    };
    let ours = fidelity(&CompressKvPolicy::default());
    let streaming = fidelity(&StreamingLlm);
    assert!(
        ours < streaming,
        "CompressKV ({ours}) should beat StreamingLLM ({streaming})"
    );
}

/// The paper's headline error metric behaves: err_max scaled by ‖V‖_max
/// is scale-equivariant under V → cV.
#[test]
fn error_metric_scale_equivariance() {
    let mut rng = Rng::seed_from(5);
    let q = Matrix::randn(&mut rng, 16, 4);
    let k = Matrix::randn(&mut rng, 64, 4);
    let v = Matrix::randn(&mut rng, 64, 3);
    let opts = CompressOpts { rank: 8, bins: 1, beta: 0.3, r_q: q.max_row_norm() };
    let exact = exact_attention(&q, &k, &v, 0.3);
    let c = compress_kv(&k, &v, &opts, &mut Rng::seed_from(9));
    let clip = ClipRange::from_values(&v);
    let o = wtd_attention(&q, &c.keys, &c.values, &c.weights, &clip, 0.3);
    let err1 = max_abs_diff(&o, &exact) / max_abs(&v);

    let v2 = v.scale(10.0);
    let exact2 = exact_attention(&q, &k, &v2, 0.3);
    let c2 = compress_kv(&k, &v2, &opts, &mut Rng::seed_from(9));
    let clip2 = ClipRange::from_values(&v2);
    let o2 = wtd_attention(&q, &c2.keys, &c2.values, &c2.weights, &clip2, 0.3);
    let err2 = max_abs_diff(&o2, &exact2) / max_abs(&v2);
    assert!((err1 - err2).abs() < 1e-5 * (1.0 + err1), "err1={err1} err2={err2}");
    let _ = norm_2inf(&v); // keep helper linked
}
