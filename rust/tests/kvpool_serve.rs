//! Integration tests for the `kvpool` memory subsystem — the PR-3
//! acceptance contract:
//!
//! * on the fixed-seed shared-prefix smoke trace, enabling prefix
//!   sharing cuts bytes-per-token by at least 30% versus sharing
//!   disabled;
//! * prefill skipping (PR-6) rides on the same trace: the sharing-on
//!   runs resume from prefix hits and compute at least 30% fewer prompt
//!   tokens than sharing-off (token-level equivalence is pinned by
//!   `rust/tests/prefill_resume.rs`);
//! * a tight pool budget (60% of the sharing-on peak) completes the same
//!   trace with **zero** admission rejections — the pressure ladder
//!   (compress cold sequences, evict cached prefix blocks) absorbs the
//!   pressure by degrading accuracy (non-zero `max_abs_err`), not
//!   availability;
//! * the `kvpool` bench (part of `wildcat bench --smoke`) writes a
//!   schema-valid `BENCH_kvpool.json` carrying those readouts;
//! * the threaded server path serves shared-prefix traffic from a
//!   budgeted pool end to end.

use std::sync::Arc;
use std::time::Duration;
use wildcat::bench::report::validate_str;
use wildcat::bench::runners::{run_all, RunCfg};
use wildcat::coordinator::{SchedulerConfig, Server, ServerConfig};
use wildcat::kvcache::StreamingLlm;
use wildcat::kvpool::KvPoolConfig;
use wildcat::model::{ModelConfig, Transformer};
use wildcat::rng::Rng;
use wildcat::util::cli::Args;
use wildcat::util::json::Json;

fn record<'a>(records: &'a [Json], name: &str) -> &'a Json {
    records
        .iter()
        .find(|r| r.get("name").and_then(Json::as_str) == Some(name))
        .unwrap_or_else(|| panic!("record {name:?} missing"))
}

fn num(r: &Json, key: &str) -> f64 {
    r.get(key)
        .and_then(Json::as_f64)
        .unwrap_or_else(|| panic!("record field {key:?} missing/non-numeric"))
}

/// The bench-level acceptance criteria, pinned against the written
/// `BENCH_kvpool.json` so CI and the test observe the same artifact.
#[test]
fn kvpool_bench_prefix_sharing_and_graceful_degradation() {
    let out = std::env::temp_dir().join(format!("wildcat_kvpool_smoke_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&out);
    std::fs::create_dir_all(&out).unwrap();

    let args = Args::parse(["--smoke"]);
    let cfg = RunCfg::from_args(&args);
    let written = run_all(&cfg, &out, Some("kvpool")).unwrap();
    assert_eq!(written.len(), 1);
    assert!(written[0].ends_with("BENCH_kvpool.json"));

    let text = std::fs::read_to_string(&written[0]).unwrap();
    let j = validate_str(&text).unwrap();
    let records = j.get("records").unwrap().as_arr().unwrap();
    assert_eq!(records.len(), 4, "one record per (sharing, budget) config");

    let on_loose = record(records, "sharing=on budget=loose");
    let off_loose = record(records, "sharing=off budget=loose");
    let on_tight = record(records, "sharing=on budget=tight");
    let off_tight = record(records, "sharing=off budget=tight");

    // 1. prefix sharing strictly reduces bytes-per-token, by >= 30%
    let bpt_on = num(on_loose, "bytes_per_token");
    let bpt_off = num(off_loose, "bytes_per_token");
    assert!(
        bpt_on <= 0.7 * bpt_off,
        "sharing saved too little: {bpt_on:.1} vs {bpt_off:.1} bytes/token"
    );
    assert!(num(on_loose, "prefix_hit_rate") > 0.5, "most admissions should hit the prefix tree");
    assert_eq!(num(off_loose, "prefix_hit_rate"), 0.0);

    // 1b. prefill skipping: sharing-on resumes from the hits and computes
    //     >= 30% fewer prompt tokens (smoke trace: 4 cold roots of 88
    //     tokens + 20 resumed tails of 24 = 832, vs 24 x 88 = 2112 cold)
    let pc_on = num(on_loose, "prefill_tokens_computed");
    let pc_off = num(off_loose, "prefill_tokens_computed");
    assert!(
        pc_on <= 0.7 * pc_off,
        "resume saved too little prefill compute: {pc_on} vs {pc_off} tokens"
    );
    assert!(num(on_loose, "prefill_tokens_skipped") > 0.0);
    assert_eq!(
        num(off_loose, "prefill_tokens_skipped"),
        0.0,
        "nothing to skip with sharing off"
    );
    // the split never loses prompt tokens: computed + skipped is the
    // same total the cold run computes outright
    assert_eq!(pc_on + num(on_loose, "prefill_tokens_skipped"), pc_off);

    // 2. the tight budget degrades gracefully: full completion, zero
    //    rejections, with the pressure absorbed by the ladder tiers
    for (name, r) in [("on_tight", on_tight), ("off_tight", off_tight)] {
        assert_eq!(num(r, "admission_rejects"), 0.0, "{name}: pool rejected admissions");
        assert_eq!(num(r, "rejected_responses"), 0.0, "{name}: requests answered empty");
        assert_eq!(num(r, "completed"), 24.0, "{name}: incomplete trace");
        assert!(
            num(r, "tier_compressions") + num(r, "evicted_blocks") > 0.0,
            "{name}: ladder never fired under a tight budget"
        );
    }
    // tight runs hold strictly less memory than the loose sharing-on run
    assert!(num(on_tight, "peak_bytes") < num(on_loose, "peak_bytes") * 1.01);

    // 3. accuracy degrades measurably (non-zero fidelity error) instead
    //    of availability: the loose runs never compressed, the tight
    //    sharing-on run did
    let err = |r: &Json| r.get("max_abs_err").and_then(Json::as_f64).unwrap();
    assert_eq!(err(on_loose), 0.0);
    let e_tight = err(on_tight);
    assert!(e_tight.is_finite() && e_tight > 0.0, "tight run should report fidelity cost");

    let _ = std::fs::remove_dir_all(&out);
}

/// End-to-end through the threaded server: a budgeted pool with prefix
/// sharing serves a burst of shared-prefix requests — every request is
/// answered with tokens, the pool dedups the prompts, and the metrics
/// snapshot carries the KV gauges.
#[test]
fn budgeted_server_serves_shared_prefix_burst() {
    let mcfg = ModelConfig { vocab: 16, d_model: 16, n_layers: 2, n_heads: 2, d_ff: 32, max_len: 256 };
    // one uncompressed 48-token sequence = 48 tokens * 4 lh * 17 floats
    let per_seq = 48 * 4 * 17;
    let cfg = ServerConfig {
        scheduler: SchedulerConfig { cache_budget: 1000, slack: 8, ..Default::default() },
        pool: KvPoolConfig {
            budget_floats: 3 * per_seq,
            block_tokens: 8,
            compress_budget: 12,
            ..Default::default()
        },
        ..Default::default()
    };
    let server = Server::spawn(cfg, Arc::new(StreamingLlm), move || {
        Transformer::random(mcfg, &mut Rng::seed_from(42))
    });

    let root: Vec<u32> = (0..40).map(|j| (j % 16) as u32).collect();
    let mut rxs = Vec::new();
    for i in 0..10u64 {
        let mut prompt = root.clone();
        prompt.extend([(i % 16) as u32; 8]); // unique suffix per request
        let (id, rx) = server.submit(prompt, 3).expect("admission queue accepts");
        rxs.push((id, rx));
    }
    for (id, rx) in rxs {
        let resp = rx.recv_timeout(Duration::from_secs(60)).unwrap();
        assert_eq!(resp.id, id);
        assert_eq!(resp.tokens.len(), 3, "pool pressure must not starve request {id}");
    }
    let counters = server.metrics().counters();
    assert_eq!(counters.completed, 10);
    assert_eq!(counters.rejected, 0);

    let snap = server.client().pool_snapshot();
    assert_eq!(snap.sequences, 0, "all sequences retired");
    assert!(snap.prefix_hits > 0, "shared roots never hit the prefix index");
    // admission enforces the budget; decode appends may transiently grow
    // past it (they never fail) before the high-water ladder reclaims —
    // allow one sequence of slack on top of the configured budget
    assert!(
        snap.peak_bytes() <= (3 * per_seq + per_seq) * 4,
        "pool peak {} blew past the budget",
        snap.peak_bytes()
    );
    let (kv_cur, kv_peak) = server.metrics().kv_bytes();
    assert!(kv_peak > 0, "scheduler never pushed KV gauges");
    assert!(kv_cur <= kv_peak);
    server.shutdown();
}
