//! Scheduler-level acceptance for prefill skipping (the PR-6 tentpole):
//!
//! * with `prefill_skip` on, admissions that hit the KV-pool radix index
//!   resume prefill from the cached rows — the generated tokens are
//!   *identical* to a cold run (the resumed tail is arithmetic-identical
//!   to the corresponding rows of a full causal prefill);
//! * `prefill_tokens_skipped` is positive under prefix sharing and the
//!   skipped + computed split accounts for every prompt token;
//! * the counter stays **zero** when prefill skipping is disabled, and
//!   when prefix sharing is off (the resume gate falls back to cold
//!   prefill rather than querying a disabled index).
//!
//! The trace uses 37-token roots over 8-token blocks, so every resume
//! boundary falls mid-block, plus one exact-duplicate prompt pair to
//! exercise the whole-prompt-match path (the lookup must leave at least
//! one tail token to compute).

use std::sync::Arc;
use wildcat::coordinator::{
    Batcher, BatcherConfig, Request, Response, Scheduler, SchedulerConfig, ServingMetrics,
};
use wildcat::kvcache::{KvCompressor, UniformKv};
use wildcat::kvpool::{KvPool, KvPoolConfig, PoolSnapshot};
use wildcat::model::{ModelConfig, Transformer};
use wildcat::rng::Rng;

const BLOCK_TOKENS: usize = 8;
const ROOT_LEN: usize = 37; // deliberately not a multiple of BLOCK_TOKENS
const SUFFIX_LEN: usize = 9;
const MAX_NEW: usize = 4;

fn tiny_cfg() -> ModelConfig {
    ModelConfig { vocab: 16, d_model: 16, n_layers: 2, n_heads: 2, d_ff: 32, max_len: 256 }
}

/// Same weights every call: both sides of an equivalence comparison must
/// run the identical model.
fn model() -> Transformer {
    Transformer::random(tiny_cfg(), &mut Rng::seed_from(42))
}

/// Eight prompts: two roots served three times each (unique suffixes),
/// then one prompt submitted twice verbatim.
fn shared_prefix_prompts() -> Vec<Vec<u32>> {
    let root = |s: u32| (0..ROOT_LEN as u32).map(|j| (s + j) % 16).collect::<Vec<u32>>();
    let mut prompts = Vec::new();
    for r in 0..2u32 {
        for i in 0..3u32 {
            let mut p = root(5 * r);
            p.extend((0..SUFFIX_LEN as u32).map(|j| (3 + r + 7 * i + j) % 16));
            prompts.push(p);
        }
    }
    let mut dup = root(11);
    dup.extend((0..SUFFIX_LEN as u32).map(|j| (j * 5) % 16));
    prompts.push(dup.clone());
    prompts.push(dup);
    prompts
}

struct RunOut {
    responses: Vec<Response>,
    computed: u64,
    skipped: u64,
    snap: PoolSnapshot,
}

/// Replay the fixed trace through a standalone scheduler and collect the
/// generated tokens plus the prefill accounting.
fn run_trace(prefill_skip: bool, prefix_sharing: bool) -> RunOut {
    let pool = Arc::new(KvPool::new(
        KvPoolConfig { block_tokens: BLOCK_TOKENS, prefix_sharing, ..Default::default() },
        Arc::new(UniformKv) as Arc<dyn KvCompressor>,
    ));
    let metrics = Arc::new(ServingMetrics::new());
    let mut s = Scheduler::with_pool(
        model(),
        SchedulerConfig { cache_budget: 1000, slack: 8, prefill_skip },
        metrics.clone(),
        7,
        pool,
    );
    let batcher = Batcher::new(BatcherConfig::default());
    let reqs: Vec<Request> = shared_prefix_prompts()
        .into_iter()
        .enumerate()
        .map(|(i, p)| Request::new(i as u64, p, MAX_NEW))
        .collect();
    let n_req = reqs.len();
    let mut responses = s.run_to_completion(reqs, &batcher);
    responses.sort_by_key(|r| r.id);
    assert_eq!(responses.len(), n_req, "every request answered exactly once");
    assert!(
        responses.iter().all(|r| r.tokens.len() == MAX_NEW),
        "no request may be pool-rejected on an unbounded budget"
    );
    let c = metrics.counters();
    let snap = s.pool().snapshot();
    RunOut {
        responses,
        computed: c.prefill_tokens_computed,
        skipped: c.prefill_tokens_skipped,
        snap,
    }
}

#[test]
fn resumed_prefill_generates_identical_tokens() {
    let resumed = run_trace(true, true);
    let cold = run_trace(false, true);
    let unshared = run_trace(true, false);
    for ((a, b), c) in resumed.responses.iter().zip(&cold.responses).zip(&unshared.responses) {
        assert_eq!(a.id, b.id);
        assert_eq!(
            a.tokens, b.tokens,
            "request {}: resumed prefill diverged from cold prefill",
            a.id
        );
        assert_eq!(a.tokens, c.tokens, "request {}: sharing=off diverged", a.id);
    }
}

#[test]
fn skipped_tokens_are_counted_and_account_for_every_prompt_token() {
    let total: u64 = shared_prefix_prompts().iter().map(|p| p.len() as u64).sum();
    let out = run_trace(true, true);
    assert!(out.skipped > 0, "shared roots never resumed from the prefix index");
    assert!(out.computed < total, "resume never saved any prefill compute");
    assert_eq!(
        out.computed + out.skipped,
        total,
        "prompt tokens lost by the computed/skipped split"
    );
    // the acceptance floor: >= 30% of prompt tokens skipped on this trace
    // (expected: 4 root hits x 32 tokens + 1 duplicate hit x 40 = 168/368)
    assert!(
        out.skipped as f64 >= 0.3 * total as f64,
        "only {}/{total} prompt tokens skipped",
        out.skipped
    );
    // skipping rides on the radix index: hits and shared tokens agree
    assert!(out.snap.prefix_hits > 0);
    assert!(out.snap.shared_tokens > 0);
}

#[test]
fn skipping_disabled_or_sharing_off_computes_every_token() {
    let total: u64 = shared_prefix_prompts().iter().map(|p| p.len() as u64).sum();
    for (name, out) in [
        ("prefill_skip=false", run_trace(false, true)),
        ("prefix_sharing=false", run_trace(true, false)),
    ] {
        assert_eq!(out.skipped, 0, "{name}: tokens skipped with resume unavailable");
        assert_eq!(out.computed, total, "{name}: cold prefill must compute the whole prompt");
    }
}
