"""Collection guard for the python test suite.

The layer-1/2 tests need CPU JAX (and hypothesis for the property
sweeps). CI runners and offline images do not always ship them, and the
test modules import jax/hypothesis at module scope — without this guard,
collection itself would error instead of skipping. Here we ignore the
modules whose hard dependencies are missing, so `pytest python/tests`
always exits green (the dependency-free tests in test_sanity.py keep the
run non-empty).
"""

import importlib.util
import os
import sys

# Make `compile.*` imports resolve exactly as the test modules expect
# (they are run with python/ on sys.path by the Makefile; keep that
# working when pytest is invoked from the repo root too).
_PY_DIR = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
if _PY_DIR not in sys.path:
    sys.path.insert(0, _PY_DIR)


def _have(mod):
    try:
        return importlib.util.find_spec(mod) is not None
    except (ImportError, ValueError):
        return False


_HAVE_JAX = _have("jax")
_HAVE_HYPOTHESIS = _have("hypothesis")

# module -> required third-party deps (all import them at module scope)
_REQUIRES = {
    "test_aot.py": _HAVE_JAX,
    "test_kernels.py": _HAVE_JAX and _HAVE_HYPOTHESIS,
    "test_model.py": _HAVE_JAX,
    # test_rpnys uses the jnp oracle (compile.kernels.ref) + hypothesis
    "test_rpnys.py": _HAVE_JAX and _HAVE_HYPOTHESIS,
}

collect_ignore = sorted(name for name, ok in _REQUIRES.items() if not ok)

if collect_ignore:
    sys.stderr.write(
        "conftest: skipping %s (missing: %s)\n"
        % (
            ", ".join(collect_ignore),
            ", ".join(
                m
                for m, have in [("jax", _HAVE_JAX), ("hypothesis", _HAVE_HYPOTHESIS)]
                if not have
            ),
        )
    )
