"""Layer-2 model tests: shapes, prefill/decode consistency (the contract
the Rust runtime depends on), and task generators."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import tasks
from compile.model import CFG, decode_step, forward_train, init_params, prefill


@pytest.fixture(scope="module")
def params():
    return init_params(jax.random.PRNGKey(0))


def test_forward_shapes(params):
    toks = jnp.zeros((2, 16), jnp.int32)
    logits = forward_train(params, toks)
    assert logits.shape == (2, 16, CFG.vocab)
    assert np.isfinite(np.asarray(logits)).all()


def test_prefill_shapes_and_padding_invariance(params):
    n = 32
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(1, CFG.vocab, size=(n,)), jnp.int32)
    logits, kc, vc = prefill(params, toks, jnp.asarray(16, jnp.int32))
    assert logits.shape == (CFG.vocab,)
    assert kc.shape == (CFG.n_layers, CFG.n_heads, n, CFG.d_head)
    assert vc.shape == kc.shape
    # causal masking: junk past `length` must not change the answer
    toks2 = toks.at[16:].set(7)
    logits2, kc2, _ = prefill(params, toks2, jnp.asarray(16, jnp.int32))
    np.testing.assert_allclose(np.asarray(logits), np.asarray(logits2), atol=1e-5)
    # caches up to length agree as well
    np.testing.assert_allclose(
        np.asarray(kc[:, :, :16]), np.asarray(kc2[:, :, :16]), atol=1e-6
    )


def test_prefill_matches_forward_train(params):
    rng = np.random.default_rng(1)
    n = 24
    toks = jnp.asarray(rng.integers(1, CFG.vocab, size=(n,)), jnp.int32)
    logits, _, _ = prefill(params, toks, jnp.asarray(n, jnp.int32))
    full = forward_train(params, toks[None])[0, -1]
    np.testing.assert_allclose(np.asarray(logits), np.asarray(full), atol=1e-4, rtol=1e-3)


def test_decode_with_exact_cache_matches_forward(params):
    """Decode over an uncompressed (w=1) cache must reproduce the full
    causal forward's next-token logits — the contract that lets the Rust
    coordinator treat compression as a drop-in."""
    rng = np.random.default_rng(2)
    n = 20
    toks = np.concatenate([[tasks.BOS], rng.integers(6, CFG.vocab, size=(n - 1,))])
    toks = jnp.asarray(toks, jnp.int32)
    # prefill the first n-1 tokens
    _, kc, vc = prefill(params, toks[: n - 1], jnp.asarray(n - 1, jnp.int32))
    w = jnp.ones((CFG.n_layers, CFG.n_heads, n - 1), jnp.float32)
    logits, new_k, new_v = decode_step(
        params, toks[n - 1], jnp.asarray(n - 1, jnp.int32), kc, vc, w
    )
    want = forward_train(params, toks[None])[0, -1]
    np.testing.assert_allclose(np.asarray(logits), np.asarray(want), atol=1e-4, rtol=1e-3)
    assert new_k.shape == (CFG.n_layers, CFG.n_heads, CFG.d_head)
    assert new_v.shape == new_k.shape


def test_decode_padding_rows_inert(params):
    rng = np.random.default_rng(3)
    n = 12
    toks = jnp.asarray(rng.integers(1, CFG.vocab, size=(n,)), jnp.int32)
    _, kc, vc = prefill(params, toks[: n - 1], jnp.asarray(n - 1, jnp.int32))
    w = jnp.ones((CFG.n_layers, CFG.n_heads, n - 1), jnp.float32)
    logits, _, _ = decode_step(params, toks[n - 1], jnp.asarray(n - 1, jnp.int32), kc, vc, w)
    # pad cache per the contract: arbitrary keys, ZERO values, zero weights
    pad = 5
    kc_p = jnp.concatenate(
        [kc, jnp.asarray(rng.normal(size=(CFG.n_layers, CFG.n_heads, pad, CFG.d_head)), jnp.float32)],
        axis=2,
    )
    vc_p = jnp.concatenate(
        [vc, jnp.zeros((CFG.n_layers, CFG.n_heads, pad, CFG.d_head), jnp.float32)],
        axis=2,
    )
    w_p = jnp.concatenate([w, jnp.zeros((CFG.n_layers, CFG.n_heads, pad))], axis=2)
    logits_p, _, _ = decode_step(
        params, toks[n - 1], jnp.asarray(n - 1, jnp.int32), kc_p, vc_p, w_p
    )
    np.testing.assert_allclose(np.asarray(logits), np.asarray(logits_p), atol=2e-4)


def test_task_generators():
    rng = np.random.default_rng(4)
    t, w, answers = tasks.gen_kv_lookup(rng, 128, CFG.vocab, n_pairs=4, n_queries=3)
    assert t.shape == (128,)
    assert t[0] == tasks.BOS
    assert len(answers) == 3
    for pos, ans in answers:
        assert t[pos] == ans
        assert w[pos] == 4.0  # answer positions carry boosted weight
    t2, w2, a2 = tasks.gen_induction(rng, 96, CFG.vocab, period=10)
    # positions ≥ period repeat with the period (position 0 is BOS-patched)
    np.testing.assert_array_equal(t2[20:90], t2[10:80])
    toks, wts = tasks.gen_batch(rng, 6, 128, CFG.vocab)
    assert toks.shape == (6, 128)
    assert wts.shape == (6, 128)
    assert (toks >= 0).all() and (toks < CFG.vocab).all()


def test_training_step_decreases_loss():
    """Three Adam steps on one batch must reduce the weighted loss (smoke
    test of the build-time training loop)."""
    from compile.train import adam_init, adam_update, loss_fn

    rng = np.random.default_rng(5)
    toks, wts = tasks.gen_batch(rng, 8, 64, CFG.vocab)
    toks = jnp.asarray(toks)
    wts = jnp.asarray(wts)
    params = init_params(jax.random.PRNGKey(1))
    opt = adam_init(params)
    l0 = float(loss_fn(params, toks, wts, CFG))
    for _ in range(5):
        loss, grads = jax.value_and_grad(loss_fn)(params, toks, wts, CFG)
        params, opt = adam_update(params, grads, opt, 1e-3)
    l1 = float(loss_fn(params, toks, wts, CFG))
    assert l1 < l0, (l0, l1)
