"""Layer-1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

Hypothesis sweeps shapes/scales; assert_allclose is the contract. This is
the CORE correctness signal for the AOT artifacts — what passes here is
exactly what the Rust runtime executes (interpret=True lowers to the same
HLO ops).
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.exact_attn import exact_attention_pallas
from compile.kernels.wtd_attn import wtd_attention_pallas


def rand(rng, *shape, scale=1.0):
    return jnp.asarray(rng.normal(size=shape) * scale, jnp.float32)


@settings(max_examples=25, deadline=None)
@given(
    m=st.sampled_from([1, 32, 128, 256]),
    r=st.integers(1, 64),
    d=st.sampled_from([4, 16, 64]),
    dv=st.sampled_from([1, 8, 64]),
    beta=st.sampled_from([0.05, 0.125, 0.5]),
    scale=st.sampled_from([0.3, 1.0, 3.0]),
    seed=st.integers(0, 2**31 - 1),
)
def test_wtd_attn_matches_ref(m, r, d, dv, beta, scale, seed):
    rng = np.random.default_rng(seed)
    q = rand(rng, m, d, scale=scale)
    ks = rand(rng, r, d, scale=scale)
    vs = rand(rng, r, dv)
    w = jnp.asarray(rng.uniform(0.0, 2.0, size=(r,)), jnp.float32)
    vmin = vs.min(axis=0)
    vmax = vs.max(axis=0)
    block_m = m if m < 128 else 128
    got = wtd_attention_pallas(q, ks, vs, w, vmin, vmax, beta=beta, block_m=block_m)
    want = ref.wtd_attention(q, ks, vs, w, vmin, vmax, beta)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5, rtol=2e-4)


@settings(max_examples=20, deadline=None)
@given(
    m=st.sampled_from([1, 64, 128, 256]),
    n=st.sampled_from([32, 128, 256]),
    d=st.sampled_from([8, 32]),
    dv=st.sampled_from([4, 32]),
    beta=st.sampled_from([0.125, 0.5]),
    seed=st.integers(0, 2**31 - 1),
)
def test_exact_attn_matches_ref(m, n, d, dv, beta, seed):
    rng = np.random.default_rng(seed)
    q = rand(rng, m, d)
    k = rand(rng, n, d)
    v = rand(rng, n, dv)
    bm = m if m < 128 else 128
    bn = n if n < 128 else 128
    got = exact_attention_pallas(q, k, v, beta=beta, block_m=bm, block_n=bn)
    want = ref.exact_attention(q, k, v, beta)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5, rtol=2e-4)


def test_wtd_attn_zero_weights_row_clips_to_zero():
    q = jnp.ones((1, 4))
    ks = jnp.ones((3, 4))
    vs = jnp.asarray(np.arange(6).reshape(3, 2), jnp.float32)
    w = jnp.zeros((3,))
    vmin = vs.min(axis=0)
    vmax = vs.max(axis=0)
    out = wtd_attention_pallas(q, ks, vs, w, vmin, vmax, beta=0.5, block_m=1)
    # denom == 0 -> 0, clipped into [vmin, vmax]
    np.testing.assert_allclose(np.asarray(out[0]), np.asarray(jnp.clip(0.0, vmin, vmax)))


def test_wtd_attn_padding_rows_are_inert():
    """Padding contract (used by the Rust decode cache): pad rows carry
    v = 0 AND w = 0. The numerator uses V_S directly (V_S = W·V already
    embeds the Nyström weights), so zero *values* silence the numerator
    and zero *weights* silence the normaliser. Keys may be arbitrary."""
    rng = np.random.default_rng(0)
    q = rand(rng, 8, 8)
    ks = rand(rng, 16, 8)
    vs = rand(rng, 16, 4)
    w = jnp.asarray(rng.uniform(0.5, 1.5, size=(16,)), jnp.float32)
    vmin = vs.min(axis=0) - 1.0  # widened clip so padding's effect on the
    vmax = vs.max(axis=0) + 1.0  # range cannot mask a real difference
    base = wtd_attention_pallas(q, ks, vs, w, vmin, vmax, beta=0.3, block_m=8)
    ks_pad = jnp.concatenate([ks, rand(rng, 5, 8)], axis=0)  # junk keys OK
    vs_pad = jnp.concatenate([vs, jnp.zeros((5, 4))], axis=0)
    w_pad = jnp.concatenate([w, jnp.zeros((5,))])
    padded = wtd_attention_pallas(q, ks_pad, vs_pad, w_pad, vmin, vmax, beta=0.3, block_m=8)
    np.testing.assert_allclose(np.asarray(base), np.asarray(padded), atol=1e-5)


def test_wtd_unit_weights_equal_exact_attention():
    rng = np.random.default_rng(1)
    q = rand(rng, 32, 8)
    k = rand(rng, 24, 8)
    v = rand(rng, 24, 4)
    w = jnp.ones((24,))
    out = wtd_attention_pallas(q, k, v, w, v.min(0), v.max(0), beta=0.4, block_m=32)
    want = ref.exact_attention(q, k, v, 0.4)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-5, rtol=1e-4)


def test_exact_attn_multi_block_boundary():
    rng = np.random.default_rng(2)
    q = rand(rng, 256, 16)
    k = rand(rng, 384, 16)
    v = rand(rng, 384, 8)
    got = exact_attention_pallas(q, k, v, beta=0.25)
    want = ref.exact_attention(q, k, v, 0.25)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5, rtol=2e-4)


def test_extreme_scale_stability():
    rng = np.random.default_rng(3)
    q = rand(rng, 4, 4, scale=30.0)
    ks = rand(rng, 8, 4, scale=30.0)
    vs = rand(rng, 8, 2)
    w = jnp.ones((8,))
    out = wtd_attention_pallas(q, ks, vs, w, vs.min(0), vs.max(0), beta=1.0, block_m=4)
    assert np.isfinite(np.asarray(out)).all()


@pytest.mark.parametrize("m,bm", [(128, 128), (256, 128), (512, 128), (64, 64)])
def test_wtd_attn_grid_tilings(m, bm):
    rng = np.random.default_rng(4)
    q = rand(rng, m, 16)
    ks = rand(rng, 32, 16)
    vs = rand(rng, 32, 8)
    w = jnp.ones((32,))
    got = wtd_attention_pallas(q, ks, vs, w, vs.min(0), vs.max(0), beta=0.25, block_m=bm)
    want = ref.wtd_attention(q, ks, vs, w, vs.min(0), vs.max(0), 0.25)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5, rtol=2e-4)
