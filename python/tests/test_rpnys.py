"""Reference-implementation tests for RPNYS, the temperature rule and the
COMPRESSKV pipeline (compile/kernels/ref.py). The Rust implementation is
cross-validated against the same invariants in rust/src/rpnys/."""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import ref


def kernel_matrix(k, scale_eff):
    k = np.asarray(k, dtype=np.float64)
    return np.exp(scale_eff * (k @ k.T))


@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(8, 48),
    d=st.integers(1, 6),
    rank=st.integers(1, 12),
    seed=st.integers(0, 10_000),
)
def test_rpnys_pivots_distinct_and_weights_shaped(n, d, rank, seed):
    rng = np.random.default_rng(seed)
    k = rng.normal(size=(n, d))
    piv, w = ref.rpnys(k, 0.3, rank, rng)
    assert len(set(piv)) == len(piv)
    assert w.shape == (len(piv), n)
    assert all(0 <= p < n for p in piv)


def test_rpnys_error_decreases_with_rank():
    rng = np.random.default_rng(0)
    k = rng.normal(size=(40, 4))
    h = kernel_matrix(k, 0.3)
    errs = []
    for rank in (2, 10, 40):
        piv, w = ref.rpnys(k, 0.3, rank, np.random.default_rng(7))
        h_hat = np.exp(0.3 * (k @ k[piv].T)) @ w
        errs.append(np.linalg.norm(h - h_hat, 2))
    assert errs[2] < errs[0]
    assert errs[2] < 1e-6 * np.linalg.norm(h, 2)  # full rank ≈ exact


def test_nystrom_weights_interpolate_at_pivots():
    rng = np.random.default_rng(1)
    k = rng.normal(size=(20, 3))
    piv, w = ref.rpnys(k, 0.5, 6, rng)
    for i, _ in enumerate(piv):
        for j, pj in enumerate(piv):
            want = 1.0 if i == j else 0.0
            assert abs(w[i, pj] - want) < 1e-6


def test_temperature_matches_eq4_shape():
    # τ² · R_Q/R_K = b0 / (2 W0(b0/(2ρ0)))  (Eq. 4)
    beta, rq, rk, n = 0.125, 4.0, 3.0, 4096
    tau = ref.temperature(beta, rq, rk, n)
    b0 = np.log(n) / (beta * rq * rk) + 2.0
    lhs = tau * tau * rq / rk
    rhs = b0 / (2.0 * ref.lambert_w0(b0 / (2.0 * ref.RHO0)))
    assert abs(lhs - rhs) < 1e-9


def test_lambert_w_identity():
    for z in (1e-6, 0.1, 1.0, 2.7, 100.0, 1e8):
        w = ref.lambert_w0(z)
        assert abs(w * np.exp(w) - z) < 1e-9 * max(z, 1.0)


def test_rho0_value():
    assert abs(ref.RHO0 - 3.19) < 0.02  # paper: ρ0 ≈ 3.19


@settings(max_examples=8, deadline=None)
@given(
    n=st.integers(24, 64),
    rank=st.integers(4, 16),
    bins=st.integers(1, 4),
    seed=st.integers(0, 10_000),
)
def test_compress_kv_shapes(n, rank, bins, seed):
    rng = np.random.default_rng(seed)
    k = rng.normal(size=(n, 4))
    v = rng.normal(size=(n, 3))
    k_s, v_s, w, idx = ref.compress_kv(k, v, 2.0, 0.25, rank, bins, rng)
    assert k_s.shape[0] == v_s.shape[0] == w.shape[0] == len(idx)
    assert k_s.shape[0] <= rank + bins
    assert len(set(idx)) == len(idx)
    # coreset keys are original rows (mean removed then re-added)
    for row, gi in enumerate(idx):
        np.testing.assert_allclose(k_s[row], k[gi], atol=1e-9)


def test_wildcat_error_decreases_with_rank():
    rng = np.random.default_rng(3)
    n = 192
    q = rng.normal(size=(64, 8)).astype(np.float32)
    k = rng.normal(size=(n, 8)).astype(np.float32)
    v = rng.normal(size=(n, 4)).astype(np.float32)
    exact = np.asarray(ref.exact_attention(q, k, v, 0.35))
    errs = []
    for rank in (4, 48, 160):
        tot = 0.0
        for s in range(3):
            o = ref.wildcat_attention(q, k, v, 0.35, rank, 1, np.random.default_rng(10 + s))
            tot += np.abs(o - exact).max()
        errs.append(tot / 3)
    assert errs[2] < errs[0], errs
    assert errs[2] < 0.3, errs
