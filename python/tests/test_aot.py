"""AOT artifact tests: manifest integrity and HLO-text round-trip through
the same xla_client conversion the export uses. Artifact-dependent tests
skip when `make artifacts` has not run yet."""

import json
import os
import struct

import jax
import jax.numpy as jnp
import numpy as np
import pytest

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_hlo_text_lowering_roundtrip():
    """The to_hlo_text conversion must produce parseable HLO with the
    expected entry computation (independent of built artifacts)."""
    from compile.aot import to_hlo_text

    fn = jax.jit(lambda x, y: (jnp.matmul(x, y) + 1.0,))
    spec = jax.ShapeDtypeStruct((4, 4), jnp.float32)
    text = to_hlo_text(fn.lower(spec, spec))
    assert "ENTRY" in text
    assert "f32[4,4]" in text


def test_weights_bin_format(tmp_path):
    from compile.aot import dump_weights_bin

    params = {"a": jnp.ones((2, 3)), "b": jnp.zeros((4,))}
    path = tmp_path / "w.bin"
    dump_weights_bin(params, str(path))
    data = path.read_bytes()
    assert data[:4] == b"WCWT"
    ver, count = struct.unpack_from("<II", data, 4)
    assert (ver, count) == (1, 2)
    # first tensor: name "a"
    off = 12
    (nlen,) = struct.unpack_from("<H", data, off)
    off += 2
    assert data[off : off + nlen] == b"a"
    off += nlen
    (ndim,) = struct.unpack_from("<B", data, off)
    off += 1
    dims = struct.unpack_from(f"<{ndim}I", data, off)
    assert dims == (2, 3)


@pytest.mark.skipif(not os.path.exists(os.path.join(ART, "manifest.json")),
                    reason="artifacts not built (run `make artifacts`)")
def test_manifest_references_existing_files():
    with open(os.path.join(ART, "manifest.json")) as f:
        manifest = json.load(f)
    assert manifest["version"] == 1
    assert manifest["model"]["vocab"] > 0
    assert len(manifest["artifacts"]) >= 2
    for art in manifest["artifacts"]:
        path = os.path.join(ART, art["file"])
        assert os.path.exists(path), art["file"]
        assert os.path.getsize(path) > 100
        assert art["inputs"] and art["outputs"]


@pytest.mark.skipif(not os.path.exists(os.path.join(ART, "weights.bin")),
                    reason="artifacts not built")
def test_weights_bin_loads_and_matches_npz():
    with np.load(os.path.join(ART, "weights.npz")) as z:
        names = set(z.files)
        embed = z["embed"]
    data = open(os.path.join(ART, "weights.bin"), "rb").read()
    assert data[:4] == b"WCWT"
    _, count = struct.unpack_from("<II", data, 4)
    assert count == len(names)
    # walk tensors, check 'embed' payload matches npz bit-exactly
    off = 12
    found = False
    for _ in range(count):
        (nlen,) = struct.unpack_from("<H", data, off)
        off += 2
        name = data[off : off + nlen].decode()
        off += nlen
        (ndim,) = struct.unpack_from("<B", data, off)
        off += 1
        dims = struct.unpack_from(f"<{ndim}I", data, off)
        off += 4 * ndim
        numel = int(np.prod(dims)) if ndim else 1
        payload = np.frombuffer(data, dtype="<f4", count=numel, offset=off)
        off += 4 * numel
        if name == "embed":
            np.testing.assert_array_equal(payload.reshape(dims), embed.astype(np.float32))
            found = True
    assert found
