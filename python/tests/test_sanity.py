"""Dependency-free sanity tests: these run on any Python ≥3.9, so the CI
python job always collects at least one test even when jax/hypothesis are
unavailable (the jax-dependent modules are ignored by conftest.py)."""

import ast
import os

HERE = os.path.dirname(os.path.abspath(__file__))
PY_ROOT = os.path.abspath(os.path.join(HERE, ".."))


def _py_sources():
    out = []
    for dirpath, _dirnames, filenames in os.walk(PY_ROOT):
        if "__pycache__" in dirpath:
            continue
        for f in filenames:
            if f.endswith(".py"):
                out.append(os.path.join(dirpath, f))
    return sorted(out)


def test_tree_has_expected_modules():
    rel = {os.path.relpath(p, PY_ROOT).replace(os.sep, "/") for p in _py_sources()}
    for expected in [
        "compile/model.py",
        "compile/aot.py",
        "compile/tasks.py",
        "compile/kernels/ref.py",
        "compile/kernels/exact_attn.py",
        "compile/kernels/wtd_attn.py",
    ]:
        assert expected in rel, "missing %s (have %d files)" % (expected, len(rel))


def test_all_python_sources_compile():
    """Every python source must at least be syntactically valid — this
    catches syntax rot even on runners without jax installed."""
    for path in _py_sources():
        with open(path, "r", encoding="utf-8") as fh:
            ast.parse(fh.read(), filename=path)
