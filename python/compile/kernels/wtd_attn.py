"""Layer-1 Pallas kernel: WTDATTN (Alg. 3), the paper's serving hot spot.

TPU-shaped design (DESIGN.md §Hardware-Adaptation): the grid tiles the
queries into VMEM-sized blocks; the whole coreset `(K_S, V_S, w)` is small
enough (r ≤ 512) to pin in VMEM, so each grid step performs two MXU
matmuls — `Q_blk @ K_Sᵀ` (logits) and `P @ V_S` (output) — plus VPU
exp/normalise/clip. Per-block max-subtraction over the r coreset logits is
exact (the softmax ratio is invariant), so no FA2-style running rescale is
needed.

`interpret=True` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers the kernel to plain HLO so the same
artifact runs under the Rust runtime. Correctness is pinned against
`ref.wtd_attention` by pytest/hypothesis.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Query-tile size: 128 rows × d=64 f32 = 32 KiB in VMEM — comfortably
# double-bufferable against the ~0.2 MiB coreset block.
DEFAULT_BLOCK_M = 128


def _wtd_attn_kernel(q_ref, ks_ref, vs_ref, w_ref, vmin_ref, vmax_ref, o_ref, *, beta):
    """One grid step: weighted softmax of a query block over the coreset."""
    q = q_ref[...]            # (bm, d)
    ks = ks_ref[...]          # (r, d)
    vs = vs_ref[...]          # (r, dv)
    w = w_ref[...]            # (r,)
    logits = beta * jnp.dot(q, ks.T, preferred_element_type=jnp.float32)
    logits = logits - jnp.max(logits, axis=-1, keepdims=True)
    p = jnp.exp(logits)       # (bm, r)
    denom = jnp.dot(p, w, preferred_element_type=jnp.float32)      # (bm,)
    num = jnp.dot(p, vs, preferred_element_type=jnp.float32)       # (bm, dv)
    safe = denom > 0
    out = jnp.where(safe[:, None], num / jnp.where(safe, denom, 1.0)[:, None], 0.0)
    o_ref[...] = jnp.clip(out, vmin_ref[...][None, :], vmax_ref[...][None, :])


@functools.partial(jax.jit, static_argnames=("beta", "block_m"))
def wtd_attention_pallas(q, k_s, v_s, w, v_min, v_max, *, beta, block_m=DEFAULT_BLOCK_M):
    """WTDATTN via Pallas. Shapes: q (m,d), k_s (r,d), v_s (r,dv), w (r,),
    v_min/v_max (dv,). m must be a multiple of block_m or smaller than it."""
    m, d = q.shape
    r, dv = v_s.shape
    bm = min(block_m, m)
    assert m % bm == 0, f"m={m} must tile by block_m={bm}"
    grid = (m // bm,)
    return pl.pallas_call(
        functools.partial(_wtd_attn_kernel, beta=beta),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, d), lambda i: (i, 0)),   # stream Q tiles
            pl.BlockSpec((r, d), lambda i: (0, 0)),    # coreset pinned
            pl.BlockSpec((r, dv), lambda i: (0, 0)),
            pl.BlockSpec((r,), lambda i: (0,)),
            pl.BlockSpec((dv,), lambda i: (0,)),
            pl.BlockSpec((dv,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, dv), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, dv), jnp.float32),
        interpret=True,
    )(q, k_s, v_s, w, v_min, v_max)
