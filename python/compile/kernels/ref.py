"""Pure-jnp correctness oracles for every Layer-1 kernel and the WildCat
pipeline. These are the ground truth the Pallas kernels and the Rust
implementations are validated against (pytest + hypothesis on this side,
`rust/tests/` integration tests on the other).

Everything here is straight-line jnp written for clarity, not speed.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def exact_attention(q, k, v, beta):
    """Softmax attention (paper Eq. 1), numerically stabilised."""
    logits = beta * (q @ k.T)
    logits = logits - logits.max(axis=-1, keepdims=True)
    p = jnp.exp(logits)
    return (p @ v) / p.sum(axis=-1, keepdims=True)


def causal_attention(q, k, v, beta):
    """Causal softmax attention for the prefill path (m == n)."""
    n = q.shape[0]
    logits = beta * (q @ k.T)
    mask = jnp.tril(jnp.ones((n, n), dtype=bool))
    logits = jnp.where(mask, logits, -jnp.inf)
    logits = logits - logits.max(axis=-1, keepdims=True)
    p = jnp.exp(logits)
    return (p @ v) / p.sum(axis=-1, keepdims=True)


def wtd_attention(q, k_s, v_s, w, v_min, v_max, beta):
    """WTDATTN (Alg. 3) with per-query max-logit stabilisation.

    q: (m, d); k_s: (r, d); v_s: (r, d_v); w: (r,);
    v_min/v_max: (d_v,) clip range. Rows with non-positive normaliser
    are zeroed before clipping, per Alg. 3.
    """
    logits = beta * (q @ k_s.T)                       # (m, r)
    logits = logits - logits.max(axis=-1, keepdims=True)
    a_hat = jnp.exp(logits)
    denom = a_hat @ w                                  # (m,)
    num = a_hat @ v_s                                  # (m, d_v)
    safe = denom > 0
    out = jnp.where(safe[:, None], num / jnp.where(safe, denom, 1.0)[:, None], 0.0)
    return jnp.clip(out, v_min[None, :], v_max[None, :])


def nystrom_weights(k, coreset_idx, scale_eff, jitter=1e-8):
    """W = h(K_S, K_S)^+ h(K_S, K) for the exponential kernel
    h(x, y) = exp(scale_eff * <x, y>). numpy f64 for stability."""
    k = np.asarray(k, dtype=np.float64)
    ks = k[np.asarray(coreset_idx)]
    h_ss = np.exp(scale_eff * (ks @ ks.T))
    h_sn = np.exp(scale_eff * (ks @ k.T))
    r = h_ss.shape[0]
    h_ss = h_ss + jitter * np.trace(h_ss) / max(r, 1) * np.eye(r)
    return np.linalg.solve(h_ss, h_sn)


def rpnys(k, scale_eff, rank, rng):
    """Sequential randomly pivoted Nyström (Alg. 1), numpy reference.

    Returns (indices, weights) with weights shaped (r, n).
    """
    k = np.asarray(k, dtype=np.float64)
    n = k.shape[0]
    rank = min(rank, n)
    res = np.exp(scale_eff * np.sum(k * k, axis=1))
    total0 = res.sum()
    floor = 1e-12 * max(total0, 1e-300) / max(n, 1)
    cols = []
    pivots = []
    for _ in range(rank):
        total = res.sum()
        if total <= 0:
            break
        s = rng.choice(n, p=np.maximum(res, 0) / np.maximum(res, 0).sum())
        c = np.exp(scale_eff * (k @ k[s]))
        for col in cols:
            c = c - col[s] * col
        rho = min(c[s], res[s])
        if rho <= floor:
            res[s] = 0.0
            continue
        c = c / np.sqrt(rho)
        res = np.maximum(res - c * c, 0.0)
        res[s] = 0.0
        cols.append(c)
        pivots.append(int(s))
    if not pivots:
        return [], np.zeros((0, n))
    w = nystrom_weights(k, pivots, scale_eff)
    return pivots, w


def lambert_w0(z, iters=24):
    """Principal Lambert-W via the Lóczi (2022) iteration (paper Thm L.1)."""
    z = float(z)
    assert z > 0, "temperature path only needs z > 0"
    e = float(np.e)
    b = (np.log(z) - np.log(np.log(z))) if z > e else z / e
    if b <= 0:
        b = z / e
    for _ in range(iters):
        b = b / (1.0 + b) * (1.0 + np.log(z) - np.log(b))
    return float(b)


RHO0 = float(np.sqrt(1.0 + np.exp(lambert_w0(2.0 / np.e**2) + 2.0)))


def temperature(beta, r_q, r_k, n):
    """The paper's closed-form rescaling rule (Eq. 4)."""
    if beta <= 0 or r_q <= 0 or r_k <= 0 or n <= 1:
        return 1.0
    b0 = np.log(n) / (beta * r_q * r_k) + 2.0
    w = lambert_w0(b0 / (2.0 * RHO0))
    if w <= 0:
        return 1.0
    return float(np.sqrt(max((r_k / r_q) * b0 / (2.0 * w), 1e-12)))


def compress_kv(k, v, r_q, beta, rank, bins, rng):
    """COMPRESSKV (Alg. 2) reference: recentre -> binned RPNYS -> weights.

    Returns (k_s, v_s, w, indices).
    """
    k = np.asarray(k, dtype=np.float64)
    v = np.asarray(v, dtype=np.float64)
    n = k.shape[0]
    if rank >= n:
        return k.copy(), v.copy(), np.ones(n), list(range(n))
    bins = max(1, min(bins, rank, n))
    rank_per_bin = -(-rank // bins)  # ceil
    mean = k.mean(axis=0)
    kc = k - mean
    base, rem = divmod(n, bins)
    out_k, out_v, out_w, out_idx = [], [], [], []
    start = 0
    for b in range(bins):
        size = base + (1 if b < rem else 0)
        kb = kc[start:start + size]
        vb = v[start:start + size]
        r_kb = float(np.sqrt((kb * kb).sum(axis=1).max())) if size else 0.0
        tau = temperature(beta, r_q, r_kb, size)
        scale_eff = beta / (tau * tau)
        piv, w = rpnys(kb, scale_eff, min(rank_per_bin, size), rng)
        if piv:
            out_k.append(kb[piv] + mean)
            out_v.append(w @ vb)
            out_w.append(w.sum(axis=1))
            out_idx.extend(int(p) + start for p in piv)
        start += size
    if not out_k:
        return np.zeros((0, k.shape[1])), np.zeros((0, v.shape[1])), np.zeros(0), []
    return (
        np.concatenate(out_k, axis=0),
        np.concatenate(out_v, axis=0),
        np.concatenate(out_w, axis=0),
        out_idx,
    )


def wildcat_attention(q, k, v, beta, rank, bins, rng):
    """WILDCAT (Alg. 4) reference."""
    q64 = np.asarray(q, dtype=np.float64)
    r_q = float(np.sqrt((q64 * q64).sum(axis=1).max()))
    v_min = np.asarray(v).min(axis=0)
    v_max = np.asarray(v).max(axis=0)
    k_s, v_s, w, _ = compress_kv(k, v, r_q, beta, rank, bins, rng)
    return np.asarray(
        wtd_attention(
            jnp.asarray(q, dtype=jnp.float32),
            jnp.asarray(k_s, dtype=jnp.float32),
            jnp.asarray(v_s, dtype=jnp.float32),
            jnp.asarray(w, dtype=jnp.float32),
            jnp.asarray(v_min, dtype=jnp.float32),
            jnp.asarray(v_max, dtype=jnp.float32),
            beta,
        )
    )
