"""Layer-1 Pallas kernel: blocked online-softmax *exact* attention — the
baseline kernel the paper measures WildCat against (FlashAttention-style
HBM↔VMEM schedule expressed with BlockSpec).

The grid is (query tiles × key tiles); each step updates a running
(max, normaliser, numerator) triple held in the output accumulators, the
TPU translation of FA2's threadblock loop. `interpret=True` for CPU-PJRT
execution (see wtd_attn.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_M = 128
DEFAULT_BLOCK_N = 128


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, *, beta, n_kv_blocks):
    kb = pl.program_id(1)

    @pl.when(kb == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)
        o_ref[...] = jnp.zeros_like(o_ref)

    q = q_ref[...]                  # (bm, d)
    k = k_ref[...]                  # (bn, d)
    v = v_ref[...]                  # (bn, dv)
    logits = beta * jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # (bm, bn)
    m_prev = m_ref[...]             # (bm,)
    l_prev = l_ref[...]
    o_prev = o_ref[...]
    m_new = jnp.maximum(m_prev, logits.max(axis=-1))
    corr = jnp.where(jnp.isfinite(m_prev), jnp.exp(m_prev - m_new), 0.0)
    p = jnp.exp(logits - m_new[:, None])
    l_new = l_prev * corr + p.sum(axis=-1)
    o_new = o_prev * corr[:, None] + jnp.dot(p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(kb == n_kv_blocks - 1)
    def _final():
        o_ref[...] = o_new / jnp.maximum(l_new, 1e-30)[:, None]

    @pl.when(kb < n_kv_blocks - 1)
    def _partial():
        o_ref[...] = o_new


@functools.partial(jax.jit, static_argnames=("beta", "block_m", "block_n"))
def exact_attention_pallas(q, k, v, *, beta, block_m=DEFAULT_BLOCK_M, block_n=DEFAULT_BLOCK_N):
    """Exact attention via a blocked online-softmax Pallas kernel."""
    m, d = q.shape
    n, dv = v.shape
    bm = min(block_m, m)
    bn = min(block_n, n)
    assert m % bm == 0, f"m={m} must tile by {bm}"
    assert n % bn == 0, f"n={n} must tile by {bn}"
    grid = (m // bm, n // bn)
    out, _m, _l = pl.pallas_call(
        functools.partial(_flash_kernel, beta=beta, n_kv_blocks=n // bn),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, d), lambda i, j: (j, 0)),
            pl.BlockSpec((bn, dv), lambda i, j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bm, dv), lambda i, j: (i, 0)),
            pl.BlockSpec((bm,), lambda i, j: (i,)),
            pl.BlockSpec((bm,), lambda i, j: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, dv), jnp.float32),
            jax.ShapeDtypeStruct((m,), jnp.float32),
            jax.ShapeDtypeStruct((m,), jnp.float32),
        ],
        interpret=True,
    )(q, k, v)
    return out
