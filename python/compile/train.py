"""Build-time training of the tiny serving LM (no optax in the image —
Adam is implemented inline). Runs once under `make artifacts`; the
resulting weights are exported to `artifacts/weights.bin` for the Rust
native model and baked into the AOT-lowered prefill/decode HLO.

Training mixture: kv-lookup retrieval + induction copying
(compile/tasks.py), the skills the Tab. 4 analogue suite evaluates under
KV-cache compression.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from . import tasks
from .model import CFG, Config, forward_train, init_params


def loss_fn(params, toks, wts, cfg: Config):
    logits = forward_train(params, toks[:, :-1], cfg)
    targets = toks[:, 1:]
    w = wts[:, 1:]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return (nll * w).sum() / w.sum()


def adam_init(params):
    zeros = jax.tree.map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree.map(jnp.zeros_like, params), "t": jnp.zeros(())}


def adam_update(params, grads, state, lr, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1.0
    m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state["m"], grads)
    v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, state["v"], grads)
    mhat_scale = 1.0 / (1.0 - b1**t)
    vhat_scale = 1.0 / (1.0 - b2**t)
    new_params = jax.tree.map(
        lambda p, m_, v_: p - lr * (m_ * mhat_scale) / (jnp.sqrt(v_ * vhat_scale) + eps),
        params,
        m,
        v,
    )
    return new_params, {"m": m, "v": v, "t": t}


def train(
    seed: int = 0,
    steps: int = 1200,
    batch: int = 32,
    seq_len: int = 256,
    lr: float = 1.5e-3,
    cfg: Config = CFG,
    log_every: int = 100,
    init_from=None,
    kv_fraction: float = 0.5,
):
    """Train and return (params, final_loss, answer_accuracy).

    `init_from` resumes from an existing parameter dict (curriculum /
    continued training)."""
    rng = np.random.default_rng(seed)
    params = init_from if init_from is not None else init_params(jax.random.PRNGKey(seed), cfg)
    opt = adam_init(params)

    @jax.jit
    def step(params, opt, toks, wts, lr_now):
        loss, grads = jax.value_and_grad(loss_fn)(params, toks, wts, cfg)
        params, opt = adam_update(params, grads, opt, lr_now)
        return params, opt, loss

    t0 = time.time()
    loss = float("nan")
    for it in range(steps):
        toks, wts = tasks.gen_batch(rng, batch, seq_len, cfg.vocab, kv_fraction)
        # cosine decay with short warmup
        warm = min(1.0, (it + 1) / 100.0)
        decay = 0.5 * (1.0 + np.cos(np.pi * it / max(steps, 1)))
        lr_now = lr * warm * (0.1 + 0.9 * decay)
        params, opt, loss = step(params, opt, jnp.asarray(toks), jnp.asarray(wts), lr_now)
        if log_every and (it % log_every == 0 or it == steps - 1):
            print(f"[train] step {it:5d} loss {float(loss):.4f} ({time.time()-t0:.1f}s)")
    acc = eval_answer_accuracy(params, seed=seed + 1, cfg=cfg, seq_len=seq_len)
    print(f"[train] done: loss={float(loss):.4f} answer-acc={acc:.3f}")
    return params, float(loss), acc


def train_full(seed: int = 0, cfg: Config = CFG, phase1_steps: int = 7000, phase2_steps: int = 1200):
    """The full from-scratch curriculum used by `make artifacts`:

    * phase 1 — 7k steps at seq 128, lr 3e-3: the induction/retrieval
      circuits form (the loss phase-transition lands around step 4k);
    * phase 2 — 1.2k steps at seq 256, lr 5e-4: length adaptation so the
      Tab. 4 evaluation contexts (256 tokens) are in-distribution.

    Returns (params, final_loss, answer_accuracy@256).
    """
    params, _loss, acc1 = train(
        seed=seed, steps=phase1_steps, seq_len=128, lr=3e-3, cfg=cfg,
        log_every=500, kv_fraction=0.6,
    )
    print(f"[train_full] phase 1 done (answer-acc@128 = {acc1:.3f})")
    params, loss, _ = train(
        seed=seed + 1, steps=phase2_steps, seq_len=256, lr=5e-4, cfg=cfg,
        log_every=300, init_from=params, kv_fraction=0.6,
    )
    acc = eval_answer_accuracy(params, seed=seed + 2, cfg=cfg, seq_len=256)
    print(f"[train_full] phase 2 done (answer-acc@256 = {acc:.3f})")
    return params, loss, acc


def eval_answer_accuracy(params, seed=1, cfg: Config = CFG, seq_len=256, trials=64):
    """Fraction of kv-lookup answers predicted correctly (uncompressed)."""
    rng = np.random.default_rng(seed)
    fwd = jax.jit(lambda p, t: forward_train(p, t, cfg))
    toks_all = np.zeros((trials, seq_len), dtype=np.int32)
    all_answers = []
    for b in range(trials):
        t, _w, answers = tasks.gen_kv_lookup(rng, seq_len, cfg.vocab, n_pairs=4)
        toks_all[b] = t
        all_answers.append(answers)
    logits = np.asarray(fwd(params, jnp.asarray(toks_all)))
    correct = 0
    total = 0
    for b, answers in enumerate(all_answers):
        for pos, ans in answers:
            total += 1
            if int(np.argmax(logits[b, pos - 1])) == ans:
                correct += 1
    return correct / max(total, 1)
