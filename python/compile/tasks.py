"""Synthetic long-context task generators (build-time twin of
`rust/src/workload/tasks.rs`).

These stand in for the paper's LongBench-E suite (Tab. 4): the model is
*trained* here on retrieval + induction mixtures with dense supervision,
and *evaluated* in Rust on 13 held-out task variants. Token conventions
are shared with the Rust side and must not drift:

    PAD=0  BOS=1  KEY=2  VAL=3  QUERY=4  SEP=5  content: 6..vocab-1

Supervision design: random filler is information-theoretically
unpredictable, so its loss is down-weighted to `FILLER_WEIGHT`; the
learnable positions (retrieval answers, repeated-segment continuations)
carry weight 1. This concentrates training on the skills the Tab. 4
analogue evaluates under KV-cache compression.
"""

from __future__ import annotations

import numpy as np

PAD, BOS, KEY, VAL, QUERY, SEP = 0, 1, 2, 3, 4, 5
CONTENT_START = 6
# Disjoint sub-ranges: keys never collide with filler, so the successor of
# a key occurrence is unambiguous (without this split, a query key can
# also appear as random filler with a random successor, making retrieval
# information-theoretically ambiguous). Mirrored in rust workload/tasks.rs.
KEY_LO, KEY_HI = 6, 20
VAL_LO, VAL_HI = 20, 34
FILLER_LO = 34
FILLER_WEIGHT = 0.05


def content_tokens(rng, size, vocab):
    return rng.integers(CONTENT_START, vocab, size=size)


def filler_tokens(rng, size, vocab):
    return rng.integers(FILLER_LO, vocab, size=size)


def gen_kv_lookup(rng, n, vocab, n_pairs=4, n_queries=4):
    """Key→value retrieval with dense queries.

    Body: `[KEY k v]` triplets scattered through random filler.
    Tail: `n_queries` blocks `[KEY k v]` — re-stating `KEY k` makes the
    answer the induction continuation of its earlier occurrence, so the
    retrieval circuit and the induction circuit coincide (the classic
    2-layer induction-head mechanism) and training converges quickly,
    while evaluation still probes genuine long-range retrieval.

    Returns (tokens (n,), weights (n,), answers) where `answers` is a
    list of (answer_pos, answer_token): logits at answer_pos−1 should
    predict answer_token.
    """
    assert n_pairs >= 1 and n_queries >= 1
    tail_len = 3 * n_queries
    body_hi = n - tail_len
    assert body_hi > 3 * n_pairs + 4, "sequence too short for the pair count"
    toks = filler_tokens(rng, n, vocab)
    wts = np.full(n, FILLER_WEIGHT, dtype=np.float32)
    toks[0] = BOS
    keys = rng.choice(np.arange(KEY_LO, KEY_HI), size=n_pairs, replace=False)
    vals = rng.integers(VAL_LO, VAL_HI, size=n_pairs)
    # non-overlapping slots of width 3 in the body
    n_slots = (body_hi - 2) // 3
    slots = 1 + rng.choice(np.arange(n_slots), size=n_pairs, replace=False) * 3
    for (s, k, v) in zip(slots, keys, vals):
        toks[s] = KEY
        toks[s + 1] = k
        toks[s + 2] = v
        # the value after an already-seen "KEY k" is predictable in
        # principle only at the tail; body values are filler-weighted
    answers = []
    pos = body_hi
    targets = rng.permutation(n_pairs).tolist()
    while len(targets) < n_queries:
        targets.append(int(rng.integers(0, n_pairs)))
    for target in targets[:n_queries]:
        toks[pos] = KEY
        toks[pos + 1] = keys[target]
        toks[pos + 2] = vals[target]
        wts[pos + 2] = 4.0
        answers.append((pos + 2, int(vals[target])))
        pos += 3
    return toks.astype(np.int32), wts, answers


def gen_induction(rng, n, vocab, period=None):
    """Copy/induction: a random segment repeats; positions ≥ period are
    predictable and carry weight 1."""
    if period is None:
        period = int(rng.integers(3, max(9, n // 4)))
    seg = content_tokens(rng, period, vocab)
    reps = -(-n // period)
    toks = np.tile(seg, reps)[:n]
    toks[0] = BOS
    wts = np.full(n, FILLER_WEIGHT, dtype=np.float32)
    wts[period:] = 1.0
    answers = [(n - 1, int(toks[n - 1]))]
    return toks.astype(np.int32), wts, answers


def gen_batch(rng, batch, n, vocab, kv_fraction=0.5):
    """Training batch mixing kv-lookup and induction rows.
    Returns (tokens (B, n) int32, loss weights (B, n) f32)."""
    toks = np.zeros((batch, n), dtype=np.int32)
    wts = np.ones((batch, n), dtype=np.float32)
    n_kv = int(round(batch * kv_fraction))
    for b in range(batch):
        if b < n_kv:
            t, w, _ = gen_kv_lookup(
                rng, n, vocab, n_pairs=int(rng.integers(2, 7)), n_queries=6
            )
        else:
            t, w, _ = gen_induction(rng, n, vocab)
        toks[b] = t
        wts[b] = w
    return toks, wts
