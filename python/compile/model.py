"""Layer-2: the JAX compute graph.

Two things live here:

1. The WildCat attention entry points that wrap the Layer-1 Pallas kernels
   (`wtd_attention_pallas`, `exact_attention_pallas`) for standalone AOT
   export.
2. A small transformer language model (2 layers, 2 heads, d=64) whose
   prefill and decode steps are AOT-lowered to HLO text and served by the
   Rust coordinator. The decode step attends over a *compressed weighted
   KV cache* `(K_S, V_S, w)` through the Pallas WTDATTN kernel — the
   paper's KV-compression serving path (Sec. 4.3) end to end.

The architecture is deliberately simple and exactly mirrored by
`rust/src/model/` (pre-norm RMSNorm, sinusoidal positions, GELU MLP,
untied unembedding) so the native and PJRT paths can be cross-checked.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .kernels.wtd_attn import wtd_attention_pallas


class Config(NamedTuple):
    vocab: int = 64
    d_model: int = 64
    n_layers: int = 2
    n_heads: int = 2
    d_ff: int = 128
    max_len: int = 1024

    @property
    def d_head(self) -> int:
        return self.d_model // self.n_heads

    @property
    def beta(self) -> float:
        return 1.0 / float(np.sqrt(self.d_head))


CFG = Config()


# --------------------------------------------------------------------------
# Parameters
# --------------------------------------------------------------------------

def init_params(key, cfg: Config = CFG):
    """Initialise parameters as a flat dict name -> array."""
    ks = jax.random.split(key, 4 + 8 * cfg.n_layers)
    it = iter(ks)
    # 1/sqrt(fan_in)-style init: attention logits need O(1) scale early or
    # the induction/retrieval circuits never receive gradient signal.
    scale = 1.0 / float(np.sqrt(cfg.d_model))
    emb_scale = 0.05
    p = {
        "embed": emb_scale * jax.random.normal(next(it), (cfg.vocab, cfg.d_model)),
        "unembed": emb_scale * jax.random.normal(next(it), (cfg.d_model, cfg.vocab)),
        "ln_f": jnp.ones((cfg.d_model,)),
    }
    for l in range(cfg.n_layers):
        p[f"l{l}.wq"] = scale * jax.random.normal(next(it), (cfg.d_model, cfg.d_model))
        p[f"l{l}.wk"] = scale * jax.random.normal(next(it), (cfg.d_model, cfg.d_model))
        p[f"l{l}.wv"] = scale * jax.random.normal(next(it), (cfg.d_model, cfg.d_model))
        p[f"l{l}.wo"] = scale * jax.random.normal(next(it), (cfg.d_model, cfg.d_model))
        p[f"l{l}.w1"] = scale * jax.random.normal(next(it), (cfg.d_model, cfg.d_ff))
        p[f"l{l}.w2"] = scale * jax.random.normal(next(it), (cfg.d_ff, cfg.d_model))
        p[f"l{l}.ln1"] = jnp.ones((cfg.d_model,))
        p[f"l{l}.ln2"] = jnp.ones((cfg.d_model,))
    return p


def positional_encoding(cfg: Config = CFG):
    """Sinusoidal positions (max_len, d_model) — no learned state, so the
    Rust mirror recomputes them bit-identically."""
    pos = np.arange(cfg.max_len)[:, None].astype(np.float64)
    dim = np.arange(cfg.d_model // 2)[None, :].astype(np.float64)
    angle = pos / np.power(10000.0, 2.0 * dim / cfg.d_model)
    enc = np.zeros((cfg.max_len, cfg.d_model), dtype=np.float32)
    enc[:, 0::2] = np.sin(angle)
    enc[:, 1::2] = np.cos(angle)
    return jnp.asarray(enc)


def rmsnorm(x, g, eps=1e-6):
    return x * g / jnp.sqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)


def gelu(x):
    return 0.5 * x * (1.0 + jnp.tanh(0.7978845608028654 * (x + 0.044715 * x**3)))


def _split_heads(x, cfg: Config):
    # (..., N, D) -> (..., H, N, dh)
    n = x.shape[-2]
    return x.reshape(*x.shape[:-1], cfg.n_heads, cfg.d_head).swapaxes(-3, -2).reshape(
        *x.shape[:-2], cfg.n_heads, n, cfg.d_head
    )


# --------------------------------------------------------------------------
# Training / prefill forward (causal, batched)
# --------------------------------------------------------------------------

def forward_train(params, tokens, cfg: Config = CFG):
    """tokens (B, N) int32 -> logits (B, N, V). Plain jnp causal attention
    (differentiable path; the Pallas kernels serve inference)."""
    b, n = tokens.shape
    pe = positional_encoding(cfg)[:n]
    x = params["embed"][tokens] + pe[None, :, :]
    mask = jnp.tril(jnp.ones((n, n), dtype=bool))
    for l in range(cfg.n_layers):
        h = rmsnorm(x, params[f"l{l}.ln1"])
        q = _split_heads(h @ params[f"l{l}.wq"], cfg)  # (B, H, N, dh)
        k = _split_heads(h @ params[f"l{l}.wk"], cfg)
        v = _split_heads(h @ params[f"l{l}.wv"], cfg)
        logits = cfg.beta * jnp.einsum("bhnd,bhmd->bhnm", q, k)
        logits = jnp.where(mask[None, None], logits, -jnp.inf)
        logits = logits - logits.max(axis=-1, keepdims=True)
        p = jnp.exp(logits)
        att = jnp.einsum("bhnm,bhmd->bhnd", p / p.sum(-1, keepdims=True), v)
        att = att.swapaxes(1, 2).reshape(b, n, cfg.d_model)
        x = x + att @ params[f"l{l}.wo"]
        h2 = rmsnorm(x, params[f"l{l}.ln2"])
        x = x + gelu(h2 @ params[f"l{l}.w1"]) @ params[f"l{l}.w2"]
    return rmsnorm(x, params["ln_f"]) @ params["unembed"]


# --------------------------------------------------------------------------
# Serving entry points (AOT-exported)
# --------------------------------------------------------------------------

def prefill(params, tokens, length, cfg: Config = CFG):
    """Prefill over a fixed-size padded token buffer.

    tokens (N,) int32 (padded), length () int32 — number of real tokens.
    Returns (logits_last (V,), k_cache (L, H, N, dh), v_cache (L, H, N, dh)).
    Causal masking makes positions ≥ length irrelevant to position
    length−1; the Rust side slices caches to `length`.
    """
    n = tokens.shape[0]
    pe = positional_encoding(cfg)[:n]
    x = params["embed"][tokens] + pe
    mask = jnp.tril(jnp.ones((n, n), dtype=bool))
    k_caches = []
    v_caches = []
    for l in range(cfg.n_layers):
        h = rmsnorm(x, params[f"l{l}.ln1"])
        q = _split_heads(h @ params[f"l{l}.wq"], cfg)  # (H, N, dh)
        k = _split_heads(h @ params[f"l{l}.wk"], cfg)
        v = _split_heads(h @ params[f"l{l}.wv"], cfg)
        k_caches.append(k)
        v_caches.append(v)
        logits = cfg.beta * jnp.einsum("hnd,hmd->hnm", q, k)
        logits = jnp.where(mask[None], logits, -jnp.inf)
        logits = logits - logits.max(axis=-1, keepdims=True)
        p = jnp.exp(logits)
        att = jnp.einsum("hnm,hmd->hnd", p / p.sum(-1, keepdims=True), v)
        att = att.swapaxes(0, 1).reshape(n, cfg.d_model)
        x = x + att @ params[f"l{l}.wo"]
        h2 = rmsnorm(x, params[f"l{l}.ln2"])
        x = x + gelu(h2 @ params[f"l{l}.w1"]) @ params[f"l{l}.w2"]
    logits_all = rmsnorm(x, params["ln_f"]) @ params["unembed"]
    logits_last = logits_all[jnp.clip(length - 1, 0, n - 1)]
    return logits_last, jnp.stack(k_caches), jnp.stack(v_caches)


def decode_step(params, token, pos, k_cache, v_cache, w_cache, cfg: Config = CFG):
    """One decode step over a compressed weighted cache.

    token () int32, pos () int32 — absolute position for the positional
    encoding. k_cache/v_cache (L, H, R, dh), w_cache (L, H, R): weighted
    coreset entries; padding rows carry weight 0 and are inert.

    Returns (logits (V,), new_k (L, H, dh), new_v (L, H, dh)) — the Rust
    coordinator appends (new_k, new_v, weight=1) to the cache.
    """
    pe = positional_encoding(cfg)
    x = params["embed"][token] + pe[pos]
    new_ks = []
    new_vs = []
    for l in range(cfg.n_layers):
        h = rmsnorm(x, params[f"l{l}.ln1"])
        q = (h @ params[f"l{l}.wq"]).reshape(cfg.n_heads, cfg.d_head)
        k_new = (h @ params[f"l{l}.wk"]).reshape(cfg.n_heads, cfg.d_head)
        v_new = (h @ params[f"l{l}.wv"]).reshape(cfg.n_heads, cfg.d_head)
        new_ks.append(k_new)
        new_vs.append(v_new)
        head_outs = []
        for hh in range(cfg.n_heads):
            # coreset ∪ {self}: the current token attends to itself with
            # weight 1 alongside the weighted cache.
            ks = jnp.concatenate([k_cache[l, hh], k_new[hh][None]], axis=0)
            vs = jnp.concatenate([v_cache[l, hh], v_new[hh][None]], axis=0)
            w = jnp.concatenate([w_cache[l, hh], jnp.ones((1,), jnp.float32)])
            v_min = vs.min(axis=0)
            v_max = vs.max(axis=0)
            out = wtd_attention_pallas(
                q[hh][None], ks, vs, w, v_min, v_max, beta=cfg.beta, block_m=1
            )
            head_outs.append(out[0])
        att = jnp.concatenate(head_outs).reshape(cfg.d_model)
        x = x + att @ params[f"l{l}.wo"]
        h2 = rmsnorm(x, params[f"l{l}.ln2"])
        x = x + gelu(h2 @ params[f"l{l}.w1"]) @ params[f"l{l}.w2"]
    logits = rmsnorm(x, params["ln_f"]) @ params["unembed"]
    return logits, jnp.stack(new_ks), jnp.stack(new_vs)
