"""AOT export: lower every serving entry point to HLO *text* and dump the
trained weights + a manifest for the Rust runtime.

HLO text (not `.serialize()`) is the interchange format: jax ≥ 0.5 emits
HloModuleProtos with 64-bit instruction ids that the image's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Exports (see DESIGN.md §1):
  * `wtd_attn_*.hlo.txt`    — standalone Layer-1 WTDATTN kernel
  * `exact_attn_*.hlo.txt`  — standalone blocked exact-attention kernel
  * `model_prefill_*.hlo.txt` / `model_decode_*.hlo.txt` — the serving LM
    (weights baked in as constants)
  * `weights.bin`           — flat tensor dump for the native Rust model
  * `manifest.json`         — name → file/shape index

Usage: `python -m compile.aot --out ../artifacts` (from python/).
"""

from __future__ import annotations

import argparse
import json
import os
import struct
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels.exact_attn import exact_attention_pallas
from .kernels.wtd_attn import wtd_attention_pallas

PREFILL_LENS = (128, 512)
DECODE_CAPS = (64, 192, 320)
TRAIN_STEPS = int(os.environ.get("WILDCAT_TRAIN_STEPS", "7000"))


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants=True: the default printer elides big constant
    # tensors (the baked model weights!) as `{...}`, which the HLO text
    # parser silently reads back as zeros.
    return comp.as_hlo_text(print_large_constants=True)


def spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def get_or_train_params(out_dir: str):
    """Load cached weights or train the LM (compile/train.py)."""
    cache = os.path.join(out_dir, "weights.npz")
    if os.path.exists(cache):
        print(f"[aot] loading cached weights from {cache}")
        with np.load(cache) as z:
            return {k: jnp.asarray(z[k]) for k in z.files}
    from .train import train_full

    print(f"[aot] training serving LM (curriculum, phase-1 {TRAIN_STEPS} steps)...")
    params, loss, acc = train_full(phase1_steps=TRAIN_STEPS)
    np.savez(cache, **{k: np.asarray(v) for k, v in params.items()})
    meta = {"final_loss": loss, "answer_accuracy": acc, "steps": TRAIN_STEPS}
    with open(os.path.join(out_dir, "training_meta.json"), "w") as f:
        json.dump(meta, f, indent=1)
    return params


def dump_weights_bin(params, path: str):
    """Binary tensor dump: magic 'WCWT', u32 version, u32 count, then per
    tensor u16 name_len, name bytes, u8 ndim, u32 dims..., f32 LE data."""
    with open(path, "wb") as f:
        f.write(b"WCWT")
        f.write(struct.pack("<II", 1, len(params)))
        for name in sorted(params):
            arr = np.asarray(params[name], dtype=np.float32)
            nb = name.encode()
            f.write(struct.pack("<H", len(nb)))
            f.write(nb)
            f.write(struct.pack("<B", arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<I", d))
            f.write(arr.astype("<f4").tobytes())


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--skip-model", action="store_true", help="kernels only")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    cfg = M.CFG
    manifest = {"version": 1, "model": dict(cfg._asdict(), beta=cfg.beta), "artifacts": []}

    def export(name, lowered, inputs, outputs):
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(args.out, fname), "w") as f:
            f.write(text)
        manifest["artifacts"].append(
            {"name": name, "file": fname, "inputs": inputs, "outputs": outputs}
        )
        print(f"[aot] wrote {fname} ({len(text)} chars)")

    # ---- standalone Layer-1 kernels ------------------------------------
    m_, r_, d_, dv_ = 256, 96, 64, 64
    wtd = jax.jit(
        lambda q, ks, vs, w, vmin, vmax: (
            wtd_attention_pallas(q, ks, vs, w, vmin, vmax, beta=float(cfg.beta)),
        )
    )
    export(
        f"wtd_attn_{m_}x{r_}x{d_}",
        wtd.lower(
            spec((m_, d_)), spec((r_, d_)), spec((r_, dv_)), spec((r_,)),
            spec((dv_,)), spec((dv_,)),
        ),
        [
            {"dtype": "f32", "shape": [m_, d_]},
            {"dtype": "f32", "shape": [r_, d_]},
            {"dtype": "f32", "shape": [r_, dv_]},
            {"dtype": "f32", "shape": [r_]},
            {"dtype": "f32", "shape": [dv_]},
            {"dtype": "f32", "shape": [dv_]},
        ],
        [{"dtype": "f32", "shape": [m_, dv_]}],
    )
    n_ = 256
    exact = jax.jit(
        lambda q, k, v: (exact_attention_pallas(q, k, v, beta=float(cfg.beta)),)
    )
    export(
        f"exact_attn_{m_}x{n_}x{d_}",
        exact.lower(spec((m_, d_)), spec((n_, d_)), spec((n_, dv_))),
        [
            {"dtype": "f32", "shape": [m_, d_]},
            {"dtype": "f32", "shape": [n_, d_]},
            {"dtype": "f32", "shape": [n_, dv_]},
        ],
        [{"dtype": "f32", "shape": [m_, dv_]}],
    )

    # ---- serving model --------------------------------------------------
    if not args.skip_model:
        params = get_or_train_params(args.out)
        dump_weights_bin(params, os.path.join(args.out, "weights.bin"))
        print("[aot] wrote weights.bin")
        l, h, dh, v = cfg.n_layers, cfg.n_heads, cfg.d_head, cfg.vocab

        for n in PREFILL_LENS:
            fn = jax.jit(lambda toks, length: M.prefill(params, toks, length, cfg))
            export(
                f"model_prefill_n{n}",
                fn.lower(spec((n,), jnp.int32), spec((), jnp.int32)),
                [{"dtype": "i32", "shape": [n]}, {"dtype": "i32", "shape": []}],
                [
                    {"dtype": "f32", "shape": [v]},
                    {"dtype": "f32", "shape": [l, h, n, dh]},
                    {"dtype": "f32", "shape": [l, h, n, dh]},
                ],
            )
        for cap in DECODE_CAPS:
            fn = jax.jit(
                lambda tok, pos, kc, vc, wc: M.decode_step(params, tok, pos, kc, vc, wc, cfg)
            )
            export(
                f"model_decode_r{cap}",
                fn.lower(
                    spec((), jnp.int32), spec((), jnp.int32),
                    spec((l, h, cap, dh)), spec((l, h, cap, dh)), spec((l, h, cap)),
                ),
                [
                    {"dtype": "i32", "shape": []},
                    {"dtype": "i32", "shape": []},
                    {"dtype": "f32", "shape": [l, h, cap, dh]},
                    {"dtype": "f32", "shape": [l, h, cap, dh]},
                    {"dtype": "f32", "shape": [l, h, cap]},
                ],
                [
                    {"dtype": "f32", "shape": [v]},
                    {"dtype": "f32", "shape": [l, h, dh]},
                    {"dtype": "f32", "shape": [l, h, dh]},
                ],
            )

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] wrote manifest.json with {len(manifest['artifacts'])} artifacts")


if __name__ == "__main__":
    sys.exit(main())
